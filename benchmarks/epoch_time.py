"""Table 2 reproduction — per-epoch training time, ours vs the naive
(HP-GNN-style) dataflow.

The FPGA cannot be timed here, so the reproduction has two layers:

  1. **Analytic model at the paper's scale**: per-epoch op counts from the
     Table-1 cost model at the paper's setup (batch 1024, NS (25, 10),
     hidden 256), for the naive dataflow vs ours.  The paper's headline is
     1.03×–1.81× over HP-GNN; our model isolates the DATAFLOW component of
     that gap (the NoC/NUMA component shows up in the ctc benchmark).
  2. **Measured at reduced scale**: wall-clock s/epoch of the actual jitted
     training step on the synthetic datasets, ours vs naive, same seeds.

``--overlap`` adds a third arm (paper §4.3, Fig. 9): the distributed train
step on a forced multi-device CPU backend, serial hypercube aggregation vs
the double-buffered pipelined schedule, same graph and seeds — reporting
the measured step-time speedup of the overlap.  Because XLA_FLAGS must be
set before jax imports, the overlap arm re-executes itself in a child
process; results land in ``BENCH_overlap.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import LayerShape, time_naive, time_ours
from repro.graph import NeighborSampler, make_dataset
from repro.graph.datasets import DATASET_STATS
from repro.models.gcn_model import GCNConfig, gcn_loss, init_gcn_params
from repro.optim import apply_updates, sgd

from .dataflow_table1 import BATCH, FANOUTS, HIDDEN, paper_layer_shapes


def _time_naive_realistic(s: LayerShape, order: str) -> float:
    """Implementation-realistic baseline transpose costs: the Aᵀ table is an
    O(e log e) COO re-sort (not Table 1's literal O(n̄e) bound) and the
    feature transpose an O(n̄d) copy — what a software HP-GNN-style port
    would actually pay.  Keeps the Table-2 comparison honest."""
    import math
    base = time_ours(s, order) - (s.h * s.d + s.b * s.c)
    resort = s.e * max(math.log2(max(s.e, 2)), 1.0)
    feat_t = (s.nbar if order == "coag" else s.n) * s.d
    return float(base + resort + feat_t + s.h * s.d)


def analytic_epoch_ratio() -> List[Dict]:
    rows = []
    for name, st in DATASET_STATS.items():
        shapes = paper_layer_shapes(name)
        batches = st.n_nodes // BATCH
        naive_lit = sum(min(time_naive(s, "coag"), time_naive(s, "agco"))
                        for s in shapes) * batches
        naive_real = sum(min(_time_naive_realistic(s, "coag"),
                             _time_naive_realistic(s, "agco"))
                         for s in shapes) * batches
        ours = sum(min(time_ours(s, "coag"), time_ours(s, "agco"))
                   for s in shapes) * batches
        rows.append({"dataset": name, "ops_naive": naive_lit,
                     "ops_naive_realistic": naive_real, "ops_ours": ours,
                     "speedup_paper_literal": naive_lit / ours,
                     "speedup": naive_real / ours})
    return rows


def measured_epoch(name: str, scale: float = 0.01, batch: int = 64,
                   n_batches: int = 8, seed: int = 0) -> Dict:
    ds = make_dataset(name, scale=scale, feat_dim=64)
    sampler = NeighborSampler(ds.graph, fanouts=FANOUTS, pad_multiple=16,
                              seed=seed)
    out = {}
    rng = np.random.default_rng(seed)
    seeds_list = [rng.permutation(ds.graph.n_nodes)[:batch]
                  for _ in range(n_batches)]
    nnz_pad = sampler.static_nnz(batch)
    batches = []
    for sd in seeds_list:
        mb = sampler.sample(sd, nnz_pad=nnz_pad,
                            rng=np.random.default_rng(0))
        x = jnp.asarray(ds.features[np.minimum(mb.input_nodes,
                                               ds.graph.n_nodes - 1)])
        pad = mb.layers[0].n_dst - len(sd)
        lab = ds.labels[np.pad(sd, (0, pad))]
        if lab.ndim > 1:
            lab = lab.argmax(-1).astype(np.int32)
        batches.append((mb.layers, x, jnp.asarray(lab)))
    for dataflow in ("ours", "naive"):
        cfg = GCNConfig(name=name, feat_dim=64, hidden=HIDDEN,
                        n_classes=ds.stats.n_classes, dataflow=dataflow)
        params = init_gcn_params(jax.random.PRNGKey(seed), cfg)
        init, update = sgd(0.05)
        opt = init(params)
        orders = ("agco", "agco")

        @jax.jit
        def step(params, opt, layers, x, lab):
            loss, g = jax.value_and_grad(gcn_loss)(params, layers, x, lab,
                                                   cfg, orders,
                                                   n_valid=batch)
            upd, opt = update(g, opt, params)
            return apply_updates(params, upd), opt, loss

        # warmup compile
        params, opt, _ = step(params, opt, *batches[0])
        t0 = time.perf_counter()
        for layers, x, lab in batches:
            params, opt, loss = step(params, opt, layers, x, lab)
        jax.block_until_ready(loss)
        out[dataflow] = (time.perf_counter() - t0) / n_batches
    out["speedup"] = out["naive"] / out["ours"]
    return out


# ---------------------------------------------------------------------------
# --overlap arm: serial vs pipelined hypercube aggregation, measured.
# ---------------------------------------------------------------------------
def _synthetic_layers(batch: int, mid: int, frontier: int, deg: int,
                      seed: int = 0):
    """Two sampled layers of a synthetic power-graph (COO, deepest last).

    Generated ONCE per benchmark run and shared by every arm, so all arms
    aggregate the same graph — and the ELL arm's cached EdgePlan (keyed on
    the COO identity) is demonstrably built once and reused across all
    measured steps.
    """
    from repro.graph.coo import from_edges

    rng = np.random.default_rng(seed)

    def layer(n_dst, n_src):
        e = n_dst * deg
        return from_edges(rng.integers(0, n_dst, e),
                          rng.integers(0, n_src, e),
                          np.abs(rng.standard_normal(e)).astype(np.float32)
                          + 0.1,
                          n_dst, n_src)

    return [layer(batch, mid), layer(mid, frontier)]


def _synthetic_sharded_batch(n_cores: int, batch: int, mid: int,
                             frontier: int, feat: int, deg: int,
                             layout: str, layers, seed: int = 0,
                             mesh=None) -> Dict:
    """Shared synthetic layers → device-ready sharded batch.

    ``mesh`` commits every leaf to its core-axis sharding at build time
    (placement once per minibatch, not per step).
    """
    from repro.distributed.gcn_train import shard_minibatch

    rng = np.random.default_rng(seed + 1)

    class _MB:                       # duck-typed MiniBatch: layers only
        pass

    _MB.layers = layers
    x = rng.standard_normal((frontier, feat)).astype(np.float32)
    labels = rng.integers(0, 16, batch).astype(np.int32)
    return shard_minibatch(_MB(), x, labels, n_cores, layout=layout,
                           mesh=mesh)


def measured_overlap(n_cores: int = 8, batch: int = 512, mid: int = 2048,
                     frontier: int = 8192, feat: int = 256,
                     hidden: int = 256, deg: int = 16, n_steps: int = 3,
                     n_trials: int = 12, n_chunks=None, seed: int = 0,
                     ell: bool = True) -> Dict:
    """Step time of the distributed GCN train step: serial vs pipelined
    (bit-exact Block-Message tiles) vs pre-reduced ELL aggregation.  Must
    run under a multi-device backend.

    All arms run back-to-back inside every trial and each reported speedup
    is the MEDIAN of the per-trial serial/arm ratios: on shared/
    oversubscribed hosts (P device threads on few physical cores) absolute
    step times swing 2-3× with background load, but the load is common-mode
    across an adjacent group, so the paired ratio is stable where a
    ratio-of-minimums is not.  Minimum per-step times are reported
    alongside for reference.  Every arm's batch is committed to its device
    sharding at build time (the fix for the recorded
    ``agg_fwd_speedup < 1`` regression — uncommitted edge arrays were
    re-laid-out on every step, a cost that grew with the blocked layout's
    leaf sizes); the ELL arm's EdgePlan is built once, cache-verified, and
    reused across all measured steps.
    """
    from repro.distributed.aggregate import shard_edges_ell
    from repro.distributed.gcn_train import init_params, make_train_step

    if n_cores & (n_cores - 1):
        raise ValueError(
            f"the hypercube schedule needs a power-of-two core count, "
            f"got --cores {n_cores}")
    if len(jax.devices()) < n_cores:
        raise RuntimeError(
            f"need {n_cores} devices, have {len(jax.devices())} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count")
    mesh = jax.make_mesh((n_cores,), ("model",))
    out: Dict = {"n_cores": n_cores, "batch": batch, "mid": mid,
                 "frontier": frontier, "feat": feat, "hidden": hidden,
                 "deg": deg, "n_steps": n_steps, "n_trials": n_trials,
                 "n_chunks": n_chunks}
    variants = [("serial", "flat", {}), ("overlap", "blocked",
                                         {"overlap": True})]
    if ell:
        variants.append(("ell", "ell", {"overlap": True, "ell": True}))
    layers = _synthetic_layers(batch, mid, frontier, deg, seed)
    from repro.kernels import edgeplan
    misses_at_start = edgeplan.cache_stats()["misses"]
    arms = {}
    for arm, layout, kw in variants:
        b = _synthetic_sharded_batch(n_cores, batch, mid, frontier, feat,
                                     deg, layout=layout, layers=layers,
                                     seed=seed, mesh=mesh)
        params = init_params(jax.random.PRNGKey(seed),
                             [(feat, hidden), (hidden, 16)])
        step = make_train_step(mesh, b["dims"], lr=0.05, n_chunks=n_chunks,
                               **kw)
        params, loss = step(params, b)        # compile
        params, loss = step(params, b)        # warmup
        jax.block_until_ready(loss)
        arms[arm] = {"step": step, "batch": b, "params": params,
                     "loss": float(loss), "times": []}
    # plan builds for THESE layers: misses added while the arms were set up
    # (only shard_edges_ell goes through the edgeplan cache)
    builds_setup = edgeplan.cache_stats()["misses"] - misses_at_start
    for _ in range(n_trials):
        for arm in arms.values():
            t0 = time.perf_counter()
            params, loss = arm["params"], None
            for _ in range(n_steps):
                params, loss = arm["step"](params, arm["batch"])
            jax.block_until_ready(loss)
            arm["times"].append((time.perf_counter() - t0) / n_steps)
    out["s_per_step_serial"] = min(arms["serial"]["times"])
    out["loss_serial"] = arms["serial"]["loss"]
    for arm in arms:
        if arm == "serial":
            continue
        suffix = "" if arm == "overlap" else f"_{arm}"
        ratios = sorted(s / o for s, o in zip(arms["serial"]["times"],
                                              arms[arm]["times"]))
        out[f"s_per_step_{arm}"] = min(arms[arm]["times"])
        out[f"trial_ratios{suffix}"] = [round(r, 3) for r in ratios]
        out[f"loss_{arm}"] = arms[arm]["loss"]
        out[f"loss_match{suffix}"] = abs(out["loss_serial"]
                                         - arms[arm]["loss"]) < 1e-5
        out[f"speedup{suffix}"] = ratios[len(ratios) // 2]  # paired median
    out.update(_measured_overlap_aggregate_op(
        n_cores, mid, frontier, hidden, deg, n_trials * n_steps, seed,
        ell=ell))
    if ell:
        # EdgePlan cache proof: the plans the measured steps consumed are
        # STILL the cached objects — re-requesting every layer's shards
        # after all timed work must add zero builder misses (a per-step or
        # per-arm rebuild would have shown up as misses during the runs;
        # the shard build inside shard_minibatch was the one and only).
        misses_before = edgeplan.cache_stats()["misses"]
        for coo in layers:
            shard_edges_ell(coo, n_cores)
        out["edge_plan_cached"] = (edgeplan.cache_stats()["misses"]
                                   == misses_before)
        out["edge_plan_builds"] = builds_setup     # one per layer expected
    return out


def _measured_overlap_aggregate_op(n_cores: int, n_dst: int, n_src: int,
                                   d: int, deg: int, n_pairs: int,
                                   seed: int, ell: bool = True) -> Dict:
    """The hot path in isolation: serial vs pipelined vs pre-reduced ELL
    aggregate, forward and forward+backward, paired per call (the arms of a
    pair run back to back so host-load noise is common-mode).

    Inside the full train step the aggregation savings can hide under
    unrelated gradient work on an oversubscribed CPU host, so the op-level
    ratios are reported alongside the step-level ones.  All edge arrays are
    committed to their core-axis sharding up front — what the training
    pipeline does once per minibatch — so the ratios measure the schedule,
    not jit's per-call re-layout of uncommitted operands.
    """
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.aggregate import (
        hypercube_aggregate, hypercube_aggregate_ell,
        hypercube_aggregate_pipelined, shard_edges, shard_edges_blocked,
        shard_edges_ell)
    from repro.distributed.sharding import leading_axis_put
    from repro.graph.coo import from_edges

    rng = np.random.default_rng(seed)
    ndim = int(np.log2(n_cores))
    e = n_dst * deg
    coo = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                     np.abs(rng.standard_normal(e)).astype(np.float32) + 0.1,
                     n_dst, n_src)
    mesh = jax.make_mesh((n_cores,), ("model",))

    def commit(a):
        # the SAME placement rule the train path uses (one transfer,
        # committed once) — so the benchmark can never measure a layout
        # the training pipeline doesn't run
        return leading_axis_put(mesh, a)

    x = commit(rng.standard_normal((n_src, d)).astype(np.float32))
    es = shard_edges(coo, n_cores)
    eb = shard_edges_blocked(coo, n_cores)
    a_s = tuple(commit(a) for a in (es.rows_global, es.cols_local, es.vals))
    a_b = tuple(commit(a) for a in (eb.rows_local, eb.cols_local, eb.vals))
    ser = jax.jit(shard_map(
        lambda r, c, v, xl: hypercube_aggregate(
            "model", ndim, n_dst, r[0], c[0], v[0], xl),
        mesh=mesh, in_specs=(P("model"),) * 4, out_specs=P("model")))
    pip = jax.jit(shard_map(
        lambda r, c, v, xl: hypercube_aggregate_pipelined(
            "model", ndim, n_dst, r[0], c[0], v[0], xl),
        mesh=mesh, in_specs=(P("model"),) * 4, out_specs=P("model")))
    gs = jax.jit(jax.grad(lambda xx: jnp.sum(ser(*a_s, xx) ** 2)))
    gp = jax.jit(jax.grad(lambda xx: jnp.sum(pip(*a_b, xx) ** 2)))

    def paired(f1, args1, f2, args2):
        jax.block_until_ready(f1(*args1))
        jax.block_until_ready(f2(*args2))
        rs = []
        for _ in range(n_pairs):
            t0 = time.perf_counter()
            jax.block_until_ready(f1(*args1))
            t1 = time.perf_counter()
            jax.block_until_ready(f2(*args2))
            rs.append((t1 - t0) / (time.perf_counter() - t1))
        rs.sort()
        return rs[len(rs) // 2]

    out = {
        "agg_fwd_speedup": paired(ser, (*a_s, x), pip, (*a_b, x)),
        "agg_fwdbwd_speedup": paired(gs, (x,), gp, (x,)),
    }
    if ell:
        from repro.distributed.sharding import leading_axis_spec
        ee = shard_edges_ell(coo, n_cores)
        tabs = jax.tree_util.tree_map(commit, ee.tables)
        especs = jax.tree_util.tree_map(leading_axis_spec, tabs)
        agg_ell = jax.jit(shard_map(
            lambda t, xl: hypercube_aggregate_ell(
                "model", ndim, n_dst,
                jax.tree_util.tree_map(lambda a: a[0], t), xl),
            mesh=mesh, in_specs=(especs, P("model")),
            out_specs=P("model")))
        ge = jax.jit(jax.grad(lambda xx: jnp.sum(agg_ell(tabs, xx) ** 2)))
        out["agg_fwd_speedup_ell"] = paired(ser, (*a_s, x), agg_ell,
                                            (tabs, x))
        out["agg_fwdbwd_speedup_ell"] = paired(gs, (x,), ge, (x,))
    return out


def run_overlap_arm(n_cores: int = 8, *, smoke: bool = False,
                    ell: bool = True,
                    out_path: str = "BENCH_overlap.json") -> Dict:
    """Re-exec the overlap measurement under a forced multi-device backend
    (XLA_FLAGS must precede the jax import) and write ``out_path``."""
    kwargs = {"n_cores": n_cores, "ell": ell}
    if smoke:
        kwargs.update(batch=128, mid=256, frontier=512, feat=64, hidden=64,
                      deg=8, n_steps=3)
    child = (
        "import json, sys; sys.path.insert(0, '.');"
        "from benchmarks.epoch_time import measured_overlap;"
        f"print(json.dumps(measured_overlap(**{kwargs!r})))"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_cores} "
                        + env.get("XLA_FLAGS", "")).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", child], env=env, cwd=root,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"overlap arm failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"## measured overlap arm ({n_cores} simulated cores)")
    print("arm,s_per_step")
    print(f"serial,{rec['s_per_step_serial']:.4f}")
    print(f"overlap,{rec['s_per_step_overlap']:.4f}")
    if "s_per_step_ell" in rec:
        print(f"ell,{rec['s_per_step_ell']:.4f}")
    print(f"# train-step speedup {rec['speedup']:.3f}x (paired median)  "
          f"loss_match={rec['loss_match']}")
    print(f"# aggregation-op speedup: fwd {rec['agg_fwd_speedup']:.3f}x  "
          f"fwd+bwd {rec['agg_fwdbwd_speedup']:.3f}x (paired median)")
    if "speedup_ell" in rec:
        print(f"# ELL arm: train-step {rec['speedup_ell']:.3f}x  "
              f"agg fwd {rec['agg_fwd_speedup_ell']:.3f}x  "
              f"fwd+bwd {rec['agg_fwdbwd_speedup_ell']:.3f}x  "
              f"loss_match={rec['loss_match_ell']}  "
              f"plan_cached={rec.get('edge_plan_cached')}")
    print(f"# (wrote {out_path})")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--overlap", action="store_true",
                    help="measure serial vs pipelined aggregation step time")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (CI): implies a quick --overlap run")
    ap.add_argument("--cores", type=int, default=8,
                    help="simulated device count for the overlap arm")
    ap.add_argument("--ell", action="store_true", default=None,
                    help="include the pre-reduced ELL arm (default: on)")
    ap.add_argument("--no-ell", dest="ell", action="store_false",
                    help="skip the ELL arm")
    args = ap.parse_args()

    if args.overlap or args.smoke:
        run_overlap_arm(args.cores, smoke=args.smoke,
                        ell=True if args.ell is None else args.ell)
        return
    _table2_main()


def _table2_main() -> None:
    print("## analytic (paper scale, dataflow component of Table 2)")
    print("dataset,ops_naive_tab1,ops_naive_realistic,ops_ours,"
          "speedup_tab1,speedup_realistic")
    for r in analytic_epoch_ratio():
        print(f"{r['dataset']},{r['ops_naive']:.4g},"
              f"{r['ops_naive_realistic']:.4g},{r['ops_ours']:.4g},"
              f"{r['speedup_paper_literal']:.2f},{r['speedup']:.3f}")
    print("# paper Table 2 overall speedup vs HP-GNN: 1.03x-1.81x "
          "(dataflow + NoC components combined)")
    print("## measured (reduced scale, s/batch on CPU)")
    print("dataset,s_naive,s_ours,speedup")
    for name in ("flickr", "reddit"):
        m = measured_epoch(name)
        print(f"{name},{m['naive']:.4f},{m['ours']:.4f},{m['speedup']:.3f}")


if __name__ == "__main__":
    main()
