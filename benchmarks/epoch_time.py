"""Table 2 reproduction — per-epoch training time, ours vs the naive
(HP-GNN-style) dataflow.

The FPGA cannot be timed here, so the reproduction has two layers:

  1. **Analytic model at the paper's scale**: per-epoch op counts from the
     Table-1 cost model at the paper's setup (batch 1024, NS (25, 10),
     hidden 256), for the naive dataflow vs ours.  The paper's headline is
     1.03×–1.81× over HP-GNN; our model isolates the DATAFLOW component of
     that gap (the NoC/NUMA component shows up in the ctc benchmark).
  2. **Measured at reduced scale**: wall-clock s/epoch of the actual jitted
     training step on the synthetic datasets, ours vs naive, same seeds.

``--overlap`` runs the measured engine-arm comparison (paper §4.3, Fig. 9):
the distributed train step on a forced multi-device CPU backend, the serial
``coo+serial`` oracle vs every arm in ``--arms`` (engine spec strings,
default ``block+pipelined,ell+pipelined`` — the old ``--ell/--no-ell`` flag
pair collapsed into specs), same graph and seeds — reporting the measured
step-time speedup per arm.  Because XLA_FLAGS must be set before jax
imports, the arm measurement re-executes itself in a child process; results
land in ``BENCH_overlap.json``.

``--input-pipeline {sync,prefetch,both}`` measures the engine-native
Trainer's per-step host-stall time under a synchronous vs a prefetching
(background-thread, depth-2) input pipeline — the overlap win of taking
sampling + per-batch layout build off the step critical path; results land
in ``BENCH_input_pipeline.json`` and, via ``run --smoke``, in
``BENCH_smoke.json`` under ``input_pipeline``.

``--feature-store`` measures feature residency: the dense device-resident
baseline vs the ``host`` and ``mmap`` :mod:`repro.featurestore` backends,
each under a synchronous and a STAGED prefetching pipeline (sample →
gather → layout → place, one thread per stage) on one bit-matching
stream, with a hot-vertex cache in front of the store; results land in
``BENCH_feature_store.json`` and ``run --smoke`` gates
``prefetch_reduces_stall`` + ``loss_match`` + ``cache_hit_rate > 0``.

``--topologies`` sweeps every registered interconnect topology (hypercube,
allpairs, ring, torus2d, plus anything registered since) over ONE
bit-matching synthetic stream: same graph, same batch, same seeds, only
the exchange wires differ.  Per topology it records the analytic exchange
plan (steps, bytes/core — ``Topology.plan``), the measured train-step
time, and the paired-median aggregate-op speedup vs the dense ``allpairs``
reference; results land in ``BENCH_topology.json``.  ``run --smoke`` gates
``hypercube_vs_allpairs_speedup > 1`` at 4 cores — the structured NoC must
beat the dense crossbar reference, or the headline topology claim is dead.

``--redundancy`` races the GraphACT-merged engine (``merge="redundancy"``
+ ``partition="mincom"``) against the plain ELL arm on one bit-matching
synthetic power-law community stream — same layers, features, labels,
initial params; results (wire-bytes reduction, aggregation FLOP reduction,
paired-median step speedup) land in ``BENCH_redundancy.json`` and ``run
--smoke`` gates ``loss_match`` + ``wire_bytes_reduction > 1.0`` +
``flop_reduction > 1.0``.

``--auto`` exercises the profile-guided planner end to end: autotune every
candidate spec on one synthetic stream (compile-and-replay, same
paired-median child-re-exec methodology), persist the winner to
``BENCH_planner.json``, then race a fresh ``Engine("auto")`` — which must
resolve through that record — against the best manual arm.  Results land
in ``BENCH_auto.json``; ``run --smoke`` gates
``auto_vs_best_manual_speedup >= 0.9`` plus exact loss bit-match and
winner/resolution agreement.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import LayerShape, time_naive, time_ours
from repro.graph import NeighborSampler, make_dataset
from repro.graph.datasets import DATASET_STATS
from repro.models.gcn_model import GCNConfig, gcn_loss, init_gcn_params
from repro.optim import apply_updates, sgd

from .dataflow_table1 import BATCH, FANOUTS, HIDDEN, paper_layer_shapes


def _time_naive_realistic(s: LayerShape, order: str) -> float:
    """Implementation-realistic baseline transpose costs: the Aᵀ table is an
    O(e log e) COO re-sort (not Table 1's literal O(n̄e) bound) and the
    feature transpose an O(n̄d) copy — what a software HP-GNN-style port
    would actually pay.  Keeps the Table-2 comparison honest."""
    import math
    base = time_ours(s, order) - (s.h * s.d + s.b * s.c)
    resort = s.e * max(math.log2(max(s.e, 2)), 1.0)
    feat_t = (s.nbar if order == "coag" else s.n) * s.d
    return float(base + resort + feat_t + s.h * s.d)


def analytic_epoch_ratio() -> List[Dict]:
    rows = []
    for name, st in DATASET_STATS.items():
        shapes = paper_layer_shapes(name)
        batches = st.n_nodes // BATCH
        naive_lit = sum(min(time_naive(s, "coag"), time_naive(s, "agco"))
                        for s in shapes) * batches
        naive_real = sum(min(_time_naive_realistic(s, "coag"),
                             _time_naive_realistic(s, "agco"))
                         for s in shapes) * batches
        ours = sum(min(time_ours(s, "coag"), time_ours(s, "agco"))
                   for s in shapes) * batches
        rows.append({"dataset": name, "ops_naive": naive_lit,
                     "ops_naive_realistic": naive_real, "ops_ours": ours,
                     "speedup_paper_literal": naive_lit / ours,
                     "speedup": naive_real / ours})
    return rows


def measured_epoch(name: str, scale: float = 0.01, batch: int = 64,
                   n_batches: int = 8, seed: int = 0) -> Dict:
    ds = make_dataset(name, scale=scale, feat_dim=64)
    sampler = NeighborSampler(ds.graph, fanouts=FANOUTS, pad_multiple=16,
                              seed=seed)
    out = {}
    rng = np.random.default_rng(seed)
    seeds_list = [rng.permutation(ds.graph.n_nodes)[:batch]
                  for _ in range(n_batches)]
    nnz_pad = sampler.static_nnz(batch)
    batches = []
    for sd in seeds_list:
        mb = sampler.sample(sd, nnz_pad=nnz_pad,
                            rng=np.random.default_rng(0))
        x = jnp.asarray(ds.features[np.minimum(mb.input_nodes,
                                               ds.graph.n_nodes - 1)])
        pad = mb.layers[0].n_dst - len(sd)
        lab = ds.labels[np.pad(sd, (0, pad))]
        if lab.ndim > 1:
            lab = lab.argmax(-1).astype(np.int32)
        batches.append((mb.layers, x, jnp.asarray(lab)))
    for dataflow in ("ours", "naive"):
        cfg = GCNConfig(name=name, feat_dim=64, hidden=HIDDEN,
                        n_classes=ds.stats.n_classes, dataflow=dataflow)
        params = init_gcn_params(jax.random.PRNGKey(seed), cfg)
        init, update = sgd(0.05)
        opt = init(params)
        orders = ("agco", "agco")

        @jax.jit
        def step(params, opt, layers, x, lab):
            loss, g = jax.value_and_grad(gcn_loss)(params, layers, x, lab,
                                                   cfg, orders,
                                                   n_valid=batch)
            upd, opt = update(g, opt, params)
            return apply_updates(params, upd), opt, loss

        # warmup compile
        params, opt, _ = step(params, opt, *batches[0])
        t0 = time.perf_counter()
        for layers, x, lab in batches:
            params, opt, loss = step(params, opt, layers, x, lab)
        jax.block_until_ready(loss)
        out[dataflow] = (time.perf_counter() - t0) / n_batches
    out["speedup"] = out["naive"] / out["ours"]
    return out


# ---------------------------------------------------------------------------
# --overlap arms: the serial oracle vs each engine spec, measured.
# ---------------------------------------------------------------------------
#: legacy metric names per spec — keeps BENCH_overlap.json keys (and the
#: compare.py tracked paths) stable across the Engine migration; an
#: unlisted spec records under its spec string
ARM_NAMES = {"coo+serial": "serial", "block+pipelined": "overlap",
             "ell+pipelined": "ell"}
DEFAULT_ARMS = ("block+pipelined", "ell+pipelined")


def _arm_name(spec: str) -> str:
    return ARM_NAMES.get(spec, spec.replace("+", "_"))


def _synthetic_layers(batch: int, mid: int, frontier: int, deg: int,
                      seed: int = 0):
    """Two sampled layers of a synthetic power-graph (COO, deepest last).

    Generated ONCE per benchmark run and shared by every arm, so all arms
    aggregate the same graph — and the ELL arm's cached EdgePlan (keyed on
    the COO identity) is demonstrably built once and reused across all
    measured steps.
    """
    from repro.graph.coo import from_edges

    rng = np.random.default_rng(seed)

    def layer(n_dst, n_src):
        e = n_dst * deg
        return from_edges(rng.integers(0, n_dst, e),
                          rng.integers(0, n_src, e),
                          np.abs(rng.standard_normal(e)).astype(np.float32)
                          + 0.1,
                          n_dst, n_src)

    return [layer(batch, mid), layer(mid, frontier)]


def _synthetic_sharded_batch(bundle, batch: int, frontier: int, feat: int,
                             layers, seed: int = 0) -> Dict:
    """Shared synthetic layers → device-ready sharded batch through one
    engine bundle (the bundle's mesh commits every leaf to its core-axis
    sharding at build time — placement once per minibatch, not per step)."""
    rng = np.random.default_rng(seed + 1)

    class _MB:                       # duck-typed MiniBatch: layers only
        pass

    _MB.layers = layers
    x = rng.standard_normal((frontier, feat)).astype(np.float32)
    labels = rng.integers(0, 16, batch).astype(np.int32)
    return bundle.shard_batch(_MB(), x, labels)


def measured_overlap(n_cores: int = 8, batch: int = 512, mid: int = 2048,
                     frontier: int = 8192, feat: int = 256,
                     hidden: int = 256, deg: int = 16, n_steps: int = 3,
                     n_trials: int = 12, n_chunks=None, seed: int = 0,
                     arms=DEFAULT_ARMS) -> Dict:
    """Step time of the distributed GCN train step: the ``coo+serial``
    oracle vs every engine spec in ``arms``.  Must run under a multi-device
    backend.

    All arms run back-to-back inside every trial and each reported speedup
    is the MEDIAN of the per-trial serial/arm ratios: on shared/
    oversubscribed hosts (P device threads on few physical cores) absolute
    step times swing 2-3× with background load, but the load is common-mode
    across an adjacent group, so the paired ratio is stable where a
    ratio-of-minimums is not.  Minimum per-step times are reported
    alongside for reference.  Every arm's batch is committed to its device
    sharding at build time (the fix for the recorded
    ``agg_fwd_speedup < 1`` regression — uncommitted edge arrays were
    re-laid-out on every step, a cost that grew with the blocked layout's
    leaf sizes); the ELL arm's EdgePlan is built once, cache-verified, and
    reused across all measured steps.
    """
    from repro.distributed.aggregate import shard_edges_ell
    from repro.distributed.gcn_train import init_params
    from repro.engine import Engine, EngineConfig

    if len(jax.devices()) < n_cores:
        raise RuntimeError(
            f"need {n_cores} devices, have {len(jax.devices())} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count")
    mesh = jax.make_mesh((n_cores,), ("model",))
    # canonicalize first (' ell' / bare 'ell' → 'ell+pipelined') so the
    # legacy-key mapping, dedupe, and the oracle filter all see one
    # spelling; the oracle always runs — listing it as an arm would only
    # race it against itself (and collide on the 'serial' metric names)
    arms = tuple(EngineConfig.from_spec(s).spec for s in arms)
    arms = tuple(dict.fromkeys(s for s in arms if s != "coo+serial"))
    out: Dict = {"n_cores": n_cores, "batch": batch, "mid": mid,
                 "frontier": frontier, "feat": feat, "hidden": hidden,
                 "deg": deg, "n_steps": n_steps, "n_trials": n_trials,
                 "n_chunks": n_chunks, "arms": list(arms)}
    variants = [("serial", "coo+serial")] + [(_arm_name(s), s) for s in arms]
    ell = "ell+pipelined" in arms
    layers = _synthetic_layers(batch, mid, frontier, deg, seed)
    from repro.kernels import edgeplan
    misses_at_start = edgeplan.cache_stats()["misses"]
    runs = {}
    for arm, spec in variants:
        # the power-of-two core-count check lives in Engine.build now
        bundle = Engine(EngineConfig.from_spec(
            spec, lr=0.05, n_chunks=n_chunks)).build(mesh)
        b = _synthetic_sharded_batch(bundle, batch, frontier, feat,
                                     layers=layers, seed=seed)
        params = init_params(jax.random.PRNGKey(seed),
                             [(feat, hidden), (hidden, 16)])
        step = bundle.train_step_fn(b["dims"])
        params, loss = step(params, b)        # compile
        params, loss = step(params, b)        # warmup
        jax.block_until_ready(loss)
        runs[arm] = {"step": step, "batch": b, "params": params,
                     "loss": float(loss), "times": []}
    # plan builds for THESE layers: misses added while the arms were set up
    # (shard_edges_ell and the engine layout caches share the edgeplan
    # cache; the layer-shard builds dominate the count)
    builds_setup = edgeplan.cache_stats()["misses"] - misses_at_start
    for _ in range(n_trials):
        for arm in runs.values():
            t0 = time.perf_counter()
            params, loss = arm["params"], None
            for _ in range(n_steps):
                params, loss = arm["step"](params, arm["batch"])
            jax.block_until_ready(loss)
            arm["times"].append((time.perf_counter() - t0) / n_steps)
    out["s_per_step_serial"] = min(runs["serial"]["times"])
    out["loss_serial"] = runs["serial"]["loss"]
    for arm in runs:
        if arm == "serial":
            continue
        suffix = "" if arm == "overlap" else f"_{arm}"
        ratios = sorted(s / o for s, o in zip(runs["serial"]["times"],
                                              runs[arm]["times"]))
        out[f"s_per_step_{arm}"] = min(runs[arm]["times"])
        out[f"trial_ratios{suffix}"] = [round(r, 3) for r in ratios]
        out[f"loss_{arm}"] = runs[arm]["loss"]
        out[f"loss_match{suffix}"] = abs(out["loss_serial"]
                                         - runs[arm]["loss"]) < 1e-5
        out[f"speedup{suffix}"] = ratios[len(ratios) // 2]  # paired median
    out.update(_measured_overlap_aggregate_op(
        n_cores, mid, frontier, hidden, deg, n_trials * n_steps, seed,
        arms=arms, n_chunks=n_chunks))
    if ell:
        # EdgePlan cache proof: the plans the measured steps consumed are
        # STILL the cached objects — re-requesting every layer's shards
        # after all timed work must add zero builder misses (a per-step or
        # per-arm rebuild would have shown up as misses during the runs;
        # the shard build inside shard_batch was the one and only).
        misses_before = edgeplan.cache_stats()["misses"]
        for coo in layers:
            shard_edges_ell(coo, n_cores)
        out["edge_plan_cached"] = (edgeplan.cache_stats()["misses"]
                                   == misses_before)
        out["edge_plan_builds"] = builds_setup     # one per layer expected
    return out


def _measured_overlap_aggregate_op(n_cores: int, n_dst: int, n_src: int,
                                   d: int, deg: int, n_pairs: int,
                                   seed: int, arms=DEFAULT_ARMS,
                                   n_chunks=None) -> Dict:
    """The hot path in isolation: the serial oracle vs every engine arm's
    aggregate, forward and forward+backward, paired per call (the arms of a
    pair run back to back so host-load noise is common-mode).

    Inside the full train step the aggregation savings can hide under
    unrelated gradient work on an oversubscribed CPU host, so the op-level
    ratios are reported alongside the step-level ones.  ``bundle.aggregator``
    commits every edge leaf to its core-axis sharding up front — the SAME
    placement rule the training pipeline runs once per minibatch — so the
    ratios measure the schedule, not jit's per-call re-layout of
    uncommitted operands.
    """
    from repro.distributed.sharding import leading_axis_put
    from repro.engine import Engine, EngineConfig
    from repro.graph.coo import from_edges

    rng = np.random.default_rng(seed)
    e = n_dst * deg
    coo = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                     np.abs(rng.standard_normal(e)).astype(np.float32) + 0.1,
                     n_dst, n_src)
    mesh = jax.make_mesh((n_cores,), ("model",))
    x = leading_axis_put(mesh,
                         rng.standard_normal((n_src, d)).astype(np.float32))
    ser = Engine("coo+serial").build(mesh, graph=coo).aggregator()
    gs = jax.jit(jax.grad(lambda xx: jnp.sum(ser(xx) ** 2)))

    def paired(f1, args1, f2, args2):
        jax.block_until_ready(f1(*args1))
        jax.block_until_ready(f2(*args2))
        rs = []
        for _ in range(n_pairs):
            t0 = time.perf_counter()
            jax.block_until_ready(f1(*args1))
            t1 = time.perf_counter()
            jax.block_until_ready(f2(*args2))
            rs.append((t1 - t0) / (time.perf_counter() - t1))
        rs.sort()
        return rs[len(rs) // 2]

    out: Dict = {}
    for spec in arms:
        name = _arm_name(spec)
        suffix = "" if name == "overlap" else f"_{name}"
        fn = Engine(EngineConfig.from_spec(spec, n_chunks=n_chunks)) \
            .build(mesh, graph=coo).aggregator()
        gf = jax.jit(jax.grad(lambda xx, fn=fn: jnp.sum(fn(xx) ** 2)))
        out[f"agg_fwd_speedup{suffix}"] = paired(ser, (x,), fn, (x,))
        out[f"agg_fwdbwd_speedup{suffix}"] = paired(gs, (x,), gf, (x,))
    return out


def run_overlap_arm(n_cores: int = 8, *, smoke: bool = False,
                    arms=DEFAULT_ARMS,
                    out_path: str = "BENCH_overlap.json") -> Dict:
    """Re-exec the engine-arm measurement under a forced multi-device
    backend (XLA_FLAGS must precede the jax import) and write ``out_path``.

    ``arms`` are engine spec strings, validated against the registry before
    the child process launches.
    """
    from repro.engine import EngineConfig

    # canonicalize + fail fast (listing registered options), dedupe, and
    # drop the oracle — it always runs as the baseline of every pair
    arms = tuple(EngineConfig.from_spec(s).spec for s in arms)
    arms = tuple(dict.fromkeys(s for s in arms if s != "coo+serial"))
    kwargs = {"n_cores": n_cores, "arms": arms}
    if smoke:
        kwargs.update(batch=128, mid=256, frontier=512, feat=64, hidden=64,
                      deg=8, n_steps=3)
    child = (
        "import json, sys; sys.path.insert(0, '.');"
        "from benchmarks.epoch_time import measured_overlap;"
        f"print(json.dumps(measured_overlap(**{kwargs!r})))"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_cores} "
                        + env.get("XLA_FLAGS", "")).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", child], env=env, cwd=root,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"overlap arm failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"## measured engine arms ({n_cores} simulated cores): "
          f"coo+serial vs {', '.join(arms)}")
    print("arm,s_per_step")
    print(f"serial,{rec['s_per_step_serial']:.4f}")
    for spec in arms:
        name = _arm_name(spec)
        print(f"{name},{rec[f's_per_step_{name}']:.4f}")
    for spec in arms:
        name = _arm_name(spec)
        suffix = "" if name == "overlap" else f"_{name}"
        print(f"# {spec}: train-step {rec[f'speedup{suffix}']:.3f}x  "
              f"agg fwd {rec[f'agg_fwd_speedup{suffix}']:.3f}x  "
              f"fwd+bwd {rec[f'agg_fwdbwd_speedup{suffix}']:.3f}x  "
              f"loss_match={rec[f'loss_match{suffix}']}"
              + (f"  plan_cached={rec.get('edge_plan_cached')}"
                 if name == "ell" else "")
              + "  (paired median)")
    print(f"# (wrote {out_path})")
    return rec


# ---------------------------------------------------------------------------
# --redundancy: GraphACT-merged ELL + mincom partitioning vs plain ELL.
# ---------------------------------------------------------------------------
def _synthetic_powerlaw_layers(batch: int, mid: int, frontier: int,
                               deg: int, n_cores: int, seed: int = 0):
    """Two sampled layers of a power-law community graph (COO, deepest
    last) — the bench graph BOTH redundancy tiers need to show a win.

    Two properties are load-bearing:

      * **Zipf hubs inside planted communities**: each destination draws
        ~90% of its neighbors from its own community's source pool under a
        zipf(1.2) rank weighting, so many rows share the same top hub
        PAIRS — the structural sharing :func:`mine_pair_redundancy`
        factors into virtual vertices.  Edge weights are GCN symmetric
        normalization (``1/sqrt(d_dst * d_src)``) — the normalization
        makes every shared pair's coefficients proportional across rows
        (ratio ``sqrt(d_v/d_u)``), which is what lets structural sharing
        actually merge; independent random weights would yield zero.
      * **Shuffled node labels in the deeper spaces**: community
        membership is a random permutation of ids for the mid/frontier
        spaces (space 0 keeps naive blocks — the batch placement mincom
        must respect), so the naive contiguous split cuts ~uniform
        cross-core traffic while ``mincom`` can recover the planted
        communities and cut it.  10% of edges rewire uniformly — the
        irreducible cross traffic.
    """
    from repro.graph.coo import from_edges

    rng = np.random.default_rng(seed)
    comm = [np.minimum(np.arange(batch) // max(batch // n_cores, 1),
                       n_cores - 1),
            rng.permutation(np.arange(mid) % n_cores),
            rng.permutation(np.arange(frontier) % n_cores)]

    def layer(n_dst, n_src, cd, cs):
        rows_l, cols_l = [], []
        for c in range(n_cores):
            dsts = np.where(cd == c)[0]
            pool = rng.permutation(np.where(cs == c)[0])
            w = 1.0 / np.arange(1.0, pool.size + 1.0) ** 1.2
            w /= w.sum()
            e_c = dsts.size * deg
            cols_c = pool[rng.choice(pool.size, e_c, p=w)]
            cross = rng.random(e_c) < 0.1
            cols_c[cross] = rng.integers(0, n_src, int(cross.sum()))
            rows_l.append(np.repeat(dsts, deg))
            cols_l.append(cols_c)
        rows = np.concatenate(rows_l).astype(np.int64)
        cols = np.concatenate(cols_l).astype(np.int64)
        # collapse duplicate (r,c) draws, then weight by GCN symmetric
        # normalization over the deduped structure
        keep = np.unique(rows * n_src + cols)
        rows, cols = keep // n_src, keep % n_src
        d_dst = np.bincount(rows, minlength=n_dst).astype(np.float64)
        d_src = np.bincount(cols, minlength=n_src).astype(np.float64)
        vals = (1.0 / np.sqrt(np.maximum(d_dst[rows] * d_src[cols], 1.0))
                ).astype(np.float32)
        return from_edges(rows, cols, vals, n_dst, n_src)

    return [layer(batch, mid, comm[0], comm[1]),
            layer(mid, frontier, comm[1], comm[2])]


def measured_redundancy(n_cores: int = 4, batch: int = 256, mid: int = 1024,
                        frontier: int = 2048, feat: int = 128,
                        hidden: int = 128, deg: int = 12, n_steps: int = 3,
                        n_trials: int = 12, seed: int = 0) -> Dict:
    """The merged arm (``merge="redundancy"`` + ``partition="mincom"``) vs
    the plain ELL engine on one bit-matching power-law stream.

    Both arms consume the SAME layers, features, labels and initial params;
    the merged arm's mincom relabeling keeps space 0 (batch/labels/logits)
    identity, so the first-step losses must agree to ≤1e-5 — reduction-
    order roundoff only.  Reported per arm: the measured exchange
    ``wire_bytes`` from the engine's plan report (post-merge row accounting
    through ``Topology.plan``), the aggregation FLOP reduction from the
    GraphACT merge stats, and the paired-median step-time ratio (arms run
    back-to-back per trial — host-load noise is common-mode, as in
    :func:`measured_overlap`).
    """
    from repro.distributed.gcn_train import init_params
    from repro.engine import Engine, EngineConfig

    if len(jax.devices()) < n_cores:
        raise RuntimeError(
            f"need {n_cores} devices, have {len(jax.devices())} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count")
    mesh = jax.make_mesh((n_cores,), ("model",))
    layers = _synthetic_powerlaw_layers(batch, mid, frontier, deg, n_cores,
                                        seed)
    rng = np.random.default_rng(seed + 1)
    x = rng.standard_normal((frontier, feat)).astype(np.float32)
    labels = rng.integers(0, 16, batch).astype(np.int32)

    class _MB:                        # duck-typed MiniBatch: layers only
        pass

    _MB.layers = layers
    arms = [("base", EngineConfig.from_spec("ell+pipelined+hypercube")),
            ("merged", EngineConfig.from_spec(
                "ell+pipelined+hypercube+mincom", merge="redundancy"))]
    out: Dict = {"n_cores": n_cores, "batch": batch, "mid": mid,
                 "frontier": frontier, "feat": feat, "hidden": hidden,
                 "deg": deg, "n_steps": n_steps, "n_trials": n_trials,
                 "base_spec": arms[0][1].spec, "merged_spec": arms[1][1].spec}
    runs = {}
    for name, cfg in arms:
        bundle = Engine(cfg).build(mesh)
        b = bundle.shard_batch(_MB(), x, labels)
        params = init_params(jax.random.PRNGKey(seed),
                             [(feat, hidden), (hidden, 16)])
        step = bundle.train_step_fn(b["dims"])
        _, loss = step(params, b)     # compile; first-step loss for the
        jax.block_until_ready(loss)   # bit-match gate (same params0)
        runs[name] = {"step": step, "batch": b, "params": params,
                      "loss": float(loss), "report": b["report"],
                      "times": []}
    for _ in range(n_trials):
        for arm in runs.values():
            t0 = time.perf_counter()
            params, loss = arm["params"], None
            for _ in range(n_steps):
                params, loss = arm["step"](params, arm["batch"])
            jax.block_until_ready(loss)
            arm["times"].append((time.perf_counter() - t0) / n_steps)
    for name, arm in runs.items():
        out[f"loss_{name}"] = arm["loss"]
        out[f"s_per_step_{name}"] = min(arm["times"])
        out[f"wire_bytes_{name}"] = arm["report"]["wire_bytes"]
    out["loss_match"] = abs(out["loss_base"] - out["loss_merged"]) < 1e-5
    out["wire_bytes_reduction"] = (out["wire_bytes_base"]
                                   / max(out["wire_bytes_merged"], 1.0))
    mrep = runs["merged"]["report"]
    out["flop_reduction"] = mrep["flop_reduction"]
    out["virtual_vertices"] = mrep["virtual_vertices"]
    out["pair_coverage"] = mrep["pair_coverage"]
    ratios = sorted(b / m for b, m in zip(runs["base"]["times"],
                                          runs["merged"]["times"]))
    out["trial_ratios"] = [round(r, 3) for r in ratios]
    out["step_speedup"] = ratios[len(ratios) // 2]     # paired median
    return out


def run_redundancy_arm(n_cores: int = 4, *, smoke: bool = False,
                       out_path: str = "BENCH_redundancy.json") -> Dict:
    """Re-exec :func:`measured_redundancy` under a forced multi-device
    backend (XLA_FLAGS must precede the jax import) and write ``out_path``.
    """
    kwargs = {"n_cores": n_cores}
    if smoke:
        kwargs.update(batch=128, mid=256, frontier=512, feat=64, hidden=64,
                      deg=8, n_steps=3, n_trials=8)
    child = (
        "import json, sys; sys.path.insert(0, '.');"
        "from benchmarks.epoch_time import measured_redundancy;"
        f"print(json.dumps(measured_redundancy(**{kwargs!r})))"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_cores} "
                        + env.get("XLA_FLAGS", "")).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", child], env=env, cwd=root,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"redundancy arm failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"## redundancy arm ({n_cores} simulated cores): "
          f"{rec['base_spec']} vs {rec['merged_spec']} (merge=redundancy)")
    print(f"# wire bytes/core: {rec['wire_bytes_base']:.3g} -> "
          f"{rec['wire_bytes_merged']:.3g}  "
          f"({rec['wire_bytes_reduction']:.2f}x reduction)")
    print(f"# aggregation FLOPs: {rec['flop_reduction']:.3f}x reduction  "
          f"({rec['virtual_vertices']:.0f} virtual vertices, "
          f"pair coverage {rec['pair_coverage']:.2f})")
    print(f"# step time: {rec['s_per_step_base']:.4f}s -> "
          f"{rec['s_per_step_merged']:.4f}s  "
          f"(paired-median speedup {rec['step_speedup']:.3f}x)  "
          f"loss_match={rec['loss_match']}")
    print(f"# (wrote {out_path})")
    return rec


# ---------------------------------------------------------------------------
# --topologies: every registered interconnect on one bit-matching stream.
# ---------------------------------------------------------------------------
def measured_topologies(n_cores: int = 4, base_spec: str = "ell+pipelined",
                        batch: int = 256, mid: int = 512,
                        frontier: int = 1024, feat: int = 128,
                        hidden: int = 128, deg: int = 8, n_steps: int = 3,
                        n_trials: int = 12, seed: int = 0) -> Dict:
    """Train-step + aggregate-op time per registered topology, one stream.

    Every topology consumes the SAME synthetic layers, features, labels and
    params (the bit-matching stream): only the exchange wires differ, so
    loss gaps measure reduction-order roundoff (must stay ≤1e-5) and time
    gaps measure the interconnect.  The dense ``allpairs`` crossbar is the
    baseline of every paired ratio — the structured topologies exist to
    beat it.  Alongside the measurements, each topology's analytic exchange
    plan (steps, bytes/core, max single-step message) is recorded from
    ``Topology.plan`` so the cost table never drifts from the code.
    """
    from repro.distributed.gcn_train import init_params
    from repro.engine import Engine, EngineConfig, supported_specs
    from repro.engine.registry import get_topology

    if len(jax.devices()) < n_cores:
        raise RuntimeError(
            f"need {n_cores} devices, have {len(jax.devices())} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count")
    base = EngineConfig.from_spec(base_spec)
    # the canonical three-part enumeration (respects the base format's
    # topology restrictions) — not a hand-built registry product
    prefix = f"{base.format}+{base.schedule}+"
    topologies = sorted(s[len(prefix):]
                        for s in supported_specs(three_part=True)
                        if s.startswith(prefix))
    mesh = jax.make_mesh((n_cores,), ("model",))
    layers = _synthetic_layers(batch, mid, frontier, deg, seed)
    out: Dict = {"n_cores": n_cores, "backend": jax.default_backend(),
                 "base_spec": f"{base.format}+"
                 f"{base.schedule}", "batch": batch, "mid": mid,
                 "frontier": frontier, "feat": feat, "hidden": hidden,
                 "deg": deg, "n_steps": n_steps, "n_trials": n_trials,
                 "topologies": topologies}
    runs = {}
    for topo in topologies:
        plan = get_topology(topo).plan(mid, feat, n_cores)
        out[f"exchange_steps_{topo}"] = plan.steps
        out[f"exchange_bytes_per_core_{topo}"] = plan.bytes_per_core
        out[f"max_step_rows_{topo}"] = plan.max_step_rows
        out[f"link_parallelism_{topo}"] = plan.link_parallelism
        bundle = Engine(EngineConfig(format=base.format,
                                     schedule=base.schedule,
                                     topology=topo, lr=0.05)).build(mesh)
        b = _synthetic_sharded_batch(bundle, batch, frontier, feat,
                                     layers=layers, seed=seed)
        params = init_params(jax.random.PRNGKey(seed),
                             [(feat, hidden), (hidden, 16)])
        step = bundle.train_step_fn(b["dims"])
        params, loss = step(params, b)        # compile
        # loss_match compares THIS loss: every arm evaluates it at the
        # identical initial params on the identical batch, so the gap is
        # forward-only reduction-order roundoff — an optimizer-amplified
        # later-step loss would make the 1e-5 gate flap on unlucky seeds
        first_loss = float(loss)
        params, loss = step(params, b)        # warmup
        jax.block_until_ready(loss)
        runs[topo] = {"step": step, "batch": b, "params": params,
                      "loss": first_loss, "times": []}
    for _ in range(n_trials):
        for arm in runs.values():       # back-to-back: load is common-mode
            t0 = time.perf_counter()
            params, loss = arm["params"], None
            for _ in range(n_steps):
                params, loss = arm["step"](params, arm["batch"])
            jax.block_until_ready(loss)
            arm["times"].append((time.perf_counter() - t0) / n_steps)
    ref_loss = runs["hypercube"]["loss"]
    out["loss_match"] = True
    for topo, arm in runs.items():
        out[f"s_per_step_{topo}"] = min(arm["times"])
        out[f"loss_{topo}"] = arm["loss"]
        if abs(arm["loss"] - ref_loss) > 1e-5:
            out["loss_match"] = False
        if topo != "allpairs":
            ratios = sorted(a / t for a, t in
                            zip(runs["allpairs"]["times"], arm["times"]))
            out[f"step_speedup_vs_allpairs_{topo}"] = \
                ratios[len(ratios) // 2]                  # paired median
    out.update(_measured_topology_aggregate_op(
        n_cores, mid, frontier, feat, deg, n_trials * n_steps, seed,
        base=base, topologies=topologies))
    # the headline ratio the smoke gates and compare.py tracks: the paper's
    # NoC vs the dense crossbar reference, on the aggregation hot path
    out["hypercube_vs_allpairs_speedup"] = \
        out["agg_fwdbwd_speedup_vs_allpairs_hypercube"]
    # fit the planner's α·steps + β·bytes cost model against THESE
    # measurements and record each topology's prediction next to its
    # measured time, so the fit error is visible in the record itself
    from repro.engine import planner
    model = planner.fit_cost_model(record=out)
    if model is not None:
        out["cost_model"] = {"alpha": model.alpha, "beta": model.beta,
                             "const": model.const}
        for topo in topologies:
            plan = get_topology(topo).plan(mid, feat, n_cores,
                                           cost_model=model)
            out[f"predicted_s_per_step_{topo}"] = plan.predicted_seconds
            meas = out[f"s_per_step_{topo}"]
            out[f"predicted_rel_err_{topo}"] = \
                abs(plan.predicted_seconds - meas) / max(meas, 1e-12)
    return out


def _measured_topology_aggregate_op(n_cores: int, n_dst: int, n_src: int,
                                    d: int, deg: int, n_pairs: int,
                                    seed: int, base, topologies) -> Dict:
    """The exchange in isolation: aggregate fwd and fwd+bwd per topology,
    paired against the allpairs reference call-by-call (same methodology
    as :func:`_measured_overlap_aggregate_op` — common-mode host load
    cancels in the per-pair ratio)."""
    from repro.distributed.sharding import leading_axis_put
    from repro.engine import Engine, EngineConfig
    from repro.graph.coo import from_edges

    rng = np.random.default_rng(seed)
    e = n_dst * deg
    coo = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                     np.abs(rng.standard_normal(e)).astype(np.float32) + 0.1,
                     n_dst, n_src)
    mesh = jax.make_mesh((n_cores,), ("model",))
    x = leading_axis_put(mesh,
                         rng.standard_normal((n_src, d)).astype(np.float32))

    def arms(topo):
        fn = Engine(EngineConfig(format=base.format, schedule=base.schedule,
                                 topology=topo)) \
            .build(mesh, graph=coo).aggregator()
        gf = jax.jit(jax.grad(lambda xx, fn=fn: jnp.sum(fn(xx) ** 2)))
        return fn, gf

    ref_fwd, ref_bwd = arms("allpairs")

    def paired(f1, f2):
        jax.block_until_ready(f1(x))
        jax.block_until_ready(f2(x))
        rs = []
        for _ in range(n_pairs):
            t0 = time.perf_counter()
            jax.block_until_ready(f1(x))
            t1 = time.perf_counter()
            jax.block_until_ready(f2(x))
            rs.append((t1 - t0) / (time.perf_counter() - t1))
        rs.sort()
        return rs[len(rs) // 2]

    out: Dict = {}
    for topo in topologies:
        if topo == "allpairs":
            continue
        fwd, bwd = arms(topo)
        out[f"agg_fwd_speedup_vs_allpairs_{topo}"] = paired(ref_fwd, fwd)
        out[f"agg_fwdbwd_speedup_vs_allpairs_{topo}"] = paired(ref_bwd, bwd)
    return out


def run_topology_arm(n_cores: int = 4, *, smoke: bool = False,
                     base_spec: str = "ell+pipelined",
                     out_path: str = "BENCH_topology.json") -> Dict:
    """Re-exec the topology sweep under a forced multi-device backend
    (XLA_FLAGS must precede the jax import) and write ``out_path``."""
    from repro.engine import EngineConfig

    base = EngineConfig.from_spec(base_spec)      # fail fast on a bad spec
    kwargs: Dict = {"n_cores": n_cores,
                    "base_spec": f"{base.format}+{base.schedule}"}
    if smoke:
        kwargs.update(batch=128, mid=256, frontier=512, feat=64, hidden=64,
                      deg=8, n_steps=3)
    child = (
        "import json, sys; sys.path.insert(0, '.');"
        "from benchmarks.epoch_time import measured_topologies;"
        f"print(json.dumps(measured_topologies(**{kwargs!r})))"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_cores} "
                        + env.get("XLA_FLAGS", "")).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", child], env=env, cwd=root,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"topology arm failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"## topology sweep ({n_cores} simulated cores, "
          f"{rec['base_spec']}+<topology>): one bit-matching stream")
    print("topology,steps,bytes/core,max_step_rows,s_per_step,"
          "predicted_s_per_step")
    for topo in rec["topologies"]:
        pred = rec.get(f"predicted_s_per_step_{topo}")
        print(f"{topo},{rec[f'exchange_steps_{topo}']},"
              f"{rec[f'exchange_bytes_per_core_{topo}']},"
              f"{rec[f'max_step_rows_{topo}']},"
              f"{rec[f's_per_step_{topo}']:.4f},"
              + ("-" if pred is None else f"{pred:.4f}"))
    for topo in rec["topologies"]:
        if topo == "allpairs":
            continue
        print(f"# {topo} vs allpairs: train-step "
              f"{rec[f'step_speedup_vs_allpairs_{topo}']:.3f}x  agg fwd "
              f"{rec[f'agg_fwd_speedup_vs_allpairs_{topo}']:.3f}x  fwd+bwd "
              f"{rec[f'agg_fwdbwd_speedup_vs_allpairs_{topo}']:.3f}x  "
              "(paired median)")
    print(f"# loss_match(<=1e-5 across topologies)={rec['loss_match']}  "
          f"hypercube_vs_allpairs={rec['hypercube_vs_allpairs_speedup']:.3f}x")
    print(f"# (wrote {out_path})")
    return rec


# ---------------------------------------------------------------------------
# --auto: the planner's Engine("auto") arm vs the best measured manual arm.
# ---------------------------------------------------------------------------
def measured_auto(n_cores: int = 4, batch: int = 256, mid: int = 512,
                  frontier: int = 1024, feat: int = 128, hidden: int = 128,
                  deg: int = 8, n_steps: int = 3, n_trials: int = 8,
                  seed: int = 0) -> Dict:
    """``Engine("auto")`` end-to-end: autotune every candidate spec on one
    synthetic stream, persist the winner to ``BENCH_planner.json``, then
    race a fresh ``Engine("auto")`` bundle (which must resolve through the
    persisted record) against the best manual arm, paired per trial.

    The auto bundle rides the SAME resolved spec as the winner, so its
    losses must bit-match the manual arm's and the paired-median ratio
    must sit near 1.0 — ``run.py --smoke`` gates
    ``auto_vs_best_manual_speedup >= 0.9`` (auto never loses the planner's
    own pick by >10%) plus ``auto_loss_match`` and
    ``resolved_matches_winner``.
    """
    from repro.distributed.gcn_train import init_params
    from repro.engine import Engine, EngineConfig, planner

    if len(jax.devices()) < n_cores:
        raise RuntimeError(
            f"need {n_cores} devices, have {len(jax.devices())} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count")
    stats = planner.GraphStats(n_dst=mid, n_src=frontier,
                               avg_deg=float(deg), feat_dim=feat)
    entry = planner.autotune(stats, n_cores=n_cores, n_steps=n_steps,
                             n_trials=n_trials, seed=seed, force=True)
    resolved = planner.resolve_spec(n_cores=n_cores, graph_stats=stats)

    def canon(s):
        return EngineConfig.from_spec(s).spec

    out: Dict = {"n_cores": n_cores, "backend": jax.default_backend(),
                 "bucket": entry["bucket"], "batch": batch, "mid": mid,
                 "frontier": frontier, "feat": feat, "hidden": hidden,
                 "deg": deg, "n_steps": n_steps, "n_trials": n_trials,
                 "best_manual_spec": canon(entry["spec"]),
                 "resolved_spec": canon(resolved),
                 "resolved_matches_winner":
                     canon(resolved) == canon(entry["spec"]),
                 "autotune_s_per_step": entry["s_per_step"]}
    mesh = jax.make_mesh((n_cores,), ("model",))
    layers = _synthetic_layers(batch, mid, frontier, deg, seed)
    runs = {}
    for name, spec in (("manual", entry["spec"]), ("auto", "auto")):
        bundle = Engine(EngineConfig.from_spec(spec, lr=0.05)).build(mesh)
        b = _synthetic_sharded_batch(bundle, batch, frontier, feat,
                                     layers=layers, seed=seed)
        params = init_params(jax.random.PRNGKey(seed),
                             [(feat, hidden), (hidden, 16)])
        step = bundle.train_step_fn(b["dims"])
        params, loss = step(params, b)        # compile; loss at init params
        first = float(loss)
        params, loss = step(params, b)        # warmup
        jax.block_until_ready(loss)
        runs[name] = {"step": step, "batch": b, "params": params,
                      "loss": first, "times": [], "spec": bundle.spec}
    out["auto_built_spec"] = runs["auto"]["spec"]
    for _ in range(n_trials):
        for arm in runs.values():     # back-to-back: load is common-mode
            t0 = time.perf_counter()
            params, loss = arm["params"], None
            for _ in range(n_steps):
                params, loss = arm["step"](params, arm["batch"])
            jax.block_until_ready(loss)
            arm["times"].append((time.perf_counter() - t0) / n_steps)
    ratios = sorted(m / a for m, a in zip(runs["manual"]["times"],
                                          runs["auto"]["times"]))
    out["s_per_step_manual"] = min(runs["manual"]["times"])
    out["s_per_step_auto"] = min(runs["auto"]["times"])
    out["auto_vs_best_manual_speedup"] = ratios[len(ratios) // 2]
    # same resolved spec on the same stream: losses must be bit-equal
    out["auto_loss_match"] = runs["auto"]["loss"] == runs["manual"]["loss"]
    return out


def run_auto_arm(n_cores: int = 4, *, smoke: bool = False,
                 out_path: str = "BENCH_auto.json") -> Dict:
    """Re-exec the auto-arm measurement under a forced multi-device
    backend and write ``out_path`` (``BENCH_planner.json`` lands in the
    CWD as a side effect — the persisted autotune winner)."""
    kwargs: Dict = {"n_cores": n_cores}
    if smoke:
        kwargs.update(batch=128, mid=256, frontier=512, feat=64, hidden=64,
                      deg=8, n_steps=3, n_trials=4)
    child = (
        "import json, sys; sys.path.insert(0, '.');"
        "from benchmarks.epoch_time import measured_auto;"
        f"print(json.dumps(measured_auto(**{kwargs!r})))"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_cores} "
                        + env.get("XLA_FLAGS", "")).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", child], env=env, cwd=root,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"auto arm failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"## auto arm ({n_cores} simulated cores): Engine('auto') vs the "
          "best manual spec")
    print("spec,s_per_step (autotune medians)")
    for spec, s in sorted(rec["autotune_s_per_step"].items(),
                          key=lambda kv: kv[1]):
        print(f"{spec},{s:.4f}")
    print(f"# winner={rec['best_manual_spec']}  "
          f"resolved={rec['resolved_spec']}  "
          f"matches={rec['resolved_matches_winner']}")
    print(f"# auto vs best manual: "
          f"{rec['auto_vs_best_manual_speedup']:.3f}x (paired median, "
          f"gate >= 0.9)  loss bit-match={rec['auto_loss_match']}")
    print(f"# (wrote {out_path}; planner record in BENCH_planner.json)")
    return rec


# ---------------------------------------------------------------------------
# --input-pipeline: host-stall per step, sync vs prefetch (the Trainer's
# async input pipeline), same stream, same spec — the overlap win recorded.
# ---------------------------------------------------------------------------
def measured_input_pipeline(n_cores: int = 4, spec: str = "ell+pipelined",
                            dataset: str = "flickr", scale: float = 0.004,
                            feat: int = 32, hidden: int = 32,
                            batch: int = 32, steps: int = 8,
                            warmup: int = 3, pad_multiple: int = 64,
                            seed: int = 0,
                            modes=("sync", "prefetch")) -> Dict:
    """Per-step host-stall time of the engine-native Trainer under each
    input pipeline.  ``sync`` pays sampling + per-batch layout build +
    placement inline on the step path; ``prefetch`` runs the identical
    work on the Trainer's producer thread (depth-2 double buffering), so
    its stall is only the queue wait the device step failed to hide.  Both
    modes consume the SAME deterministic batch stream (seeded pipeline),
    so their loss trajectories must match bit-for-bit — recorded as
    ``input_loss_match``.  Warmup steps absorb the jit compiles (shape
    signatures are coarsened via ``pad_multiple``) and prefill the queue;
    stall counters reset before the measured window.
    """
    from repro.launch.trainer import Trainer

    if len(jax.devices()) < n_cores:
        raise RuntimeError(
            f"need {n_cores} devices, have {len(jax.devices())} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count")
    out: Dict = {"n_cores": n_cores, "spec": spec, "dataset": dataset,
                 "batch": batch, "steps": steps, "modes": list(modes)}
    losses = {}
    for mode in modes:
        tr = Trainer(spec, dataset, n_cores=n_cores, scale=scale,
                     feat_dim=feat, hidden=hidden, batch_size=batch,
                     lr=0.05, seed=seed, input_pipeline=mode,
                     pad_multiple=pad_multiple, val_batches=0)
        try:
            tr.train_steps(warmup)        # compile + queue prefill
            tr.reset_stall_stats()
            t0 = time.perf_counter()
            losses[mode] = tr.train_steps(steps)
            dt = time.perf_counter() - t0
            out[f"host_stall_s_per_step_{mode}"] = tr.stall_per_step
            out[f"s_per_step_{mode}"] = dt / steps
        finally:
            tr.close()
    if len(losses) == 2:
        a, b = (losses[m] for m in modes)
        out["input_loss_match"] = bool(
            max(abs(x - y) for x, y in zip(a, b)) == 0.0)
        stall_s = out["host_stall_s_per_step_sync"]
        stall_p = out["host_stall_s_per_step_prefetch"]
        out["stall_reduction"] = stall_s / max(stall_p, 1e-9)
        out["prefetch_reduces_stall"] = bool(stall_p < stall_s)
    return out


def run_input_pipeline_arm(n_cores: int = 4, *, smoke: bool = False,
                           spec: str = "ell+pipelined",
                           modes=("sync", "prefetch"),
                           out_path: str = "BENCH_input_pipeline.json"
                           ) -> Dict:
    """Re-exec the input-pipeline measurement under a forced multi-device
    backend and write ``out_path`` (same child-process pattern as
    :func:`run_overlap_arm`: XLA_FLAGS must precede the jax import)."""
    kwargs = {"n_cores": n_cores, "spec": spec, "modes": tuple(modes)}
    if smoke:
        kwargs.update(scale=0.003, feat=32, hidden=32, batch=32, steps=6,
                      warmup=2)
    child = (
        "import json, sys; sys.path.insert(0, '.');"
        "from benchmarks.epoch_time import measured_input_pipeline;"
        f"print(json.dumps(measured_input_pipeline(**{kwargs!r})))"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_cores} "
                        + env.get("XLA_FLAGS", "")).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", child], env=env, cwd=root,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"input-pipeline arm failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"## input pipeline ({n_cores} simulated cores, {spec}): "
          "host-stall per step, sync vs prefetch")
    print("mode,host_stall_s_per_step,s_per_step")
    for mode in rec["modes"]:
        print(f"{mode},{rec[f'host_stall_s_per_step_{mode}']:.4f},"
              f"{rec[f's_per_step_{mode}']:.4f}")
    if "stall_reduction" in rec:
        print(f"# prefetch cuts host stall {rec['stall_reduction']:.1f}x "
              f"(strictly less: {rec['prefetch_reduces_stall']}, "
              f"loss bit-match: {rec['input_loss_match']})")
    print(f"# (wrote {out_path})")
    return rec


# ---------------------------------------------------------------------------
# --feature-store: device-resident vs out-of-core features, one bit-matching
# stream — host-stall, gather traffic, and hot-vertex cache hit rate.
# ---------------------------------------------------------------------------
def measured_feature_store(n_cores: int = 4, spec: str = "ell+pipelined",
                           dataset: str = "flickr", scale: float = 0.004,
                           feat: int = 32, hidden: int = 32,
                           batch: int = 32, steps: int = 8,
                           warmup: int = 3, pad_multiple: int = 64,
                           seed: int = 0, cache_capacity: int = 64,
                           modes=("device", "host", "mmap")) -> Dict:
    """The Trainer on each feature residency mode, sync vs staged prefetch.

    ``device`` is the dense in-memory baseline; ``host``/``mmap`` are
    registered :mod:`repro.featurestore` backends with a hot-vertex cache
    in front.  Every mode consumes the SAME deterministic batch stream
    (store-backed :func:`make_dataset` generation is bit-identical to the
    dense path at the same seed), so all loss trajectories must bit-match
    — recorded as ``loss_match``.  Per store mode it records the sync
    host-stall (gather + layout + placement inline on the step path), the
    staged-prefetch stall (sample → gather → layout → place, each stage on
    its own thread — only the queue wait the device step failed to hide),
    the store bytes actually gathered in the measured window, and the
    cache hit rate.  Headline keys (``stall_reduction``,
    ``cache_hit_rate``, ``prefetch_reduces_stall``) come from the mmap
    mode — the tier where a synchronous gather would pay disk latency on
    the critical path.
    """
    from repro.launch.trainer import Trainer

    if len(jax.devices()) < n_cores:
        raise RuntimeError(
            f"need {n_cores} devices, have {len(jax.devices())} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count")
    out: Dict = {"n_cores": n_cores, "spec": spec, "dataset": dataset,
                 "batch": batch, "steps": steps, "modes": list(modes),
                 "cache_capacity": cache_capacity}
    ref_losses = None
    out["loss_match"] = True
    for mode in modes:
        ds = make_dataset(dataset, scale=scale, feat_dim=feat,
                          features="dense" if mode == "device" else mode)
        cap = 0 if mode == "device" else cache_capacity
        try:
            for pipe in ("sync", "prefetch"):
                tr = Trainer(spec, ds, n_cores=n_cores, hidden=hidden,
                             batch_size=batch, lr=0.05, seed=seed,
                             input_pipeline=pipe,
                             pad_multiple=pad_multiple, val_batches=0,
                             cache_capacity=cap)
                try:
                    tr.train_steps(warmup)    # compile + queue prefill
                    tr.reset_stall_stats()
                    if tr.cache is not None:
                        tr.cache.reset_stats()
                    g0 = tr.store.bytes_gathered if tr.store else 0
                    t0 = time.perf_counter()
                    losses = tr.train_steps(steps)
                    dt = time.perf_counter() - t0
                    out[f"host_stall_s_per_step_{mode}_{pipe}"] = \
                        tr.stall_per_step
                    out[f"s_per_step_{mode}_{pipe}"] = dt / steps
                    if tr.store is not None and pipe == "prefetch":
                        # window delta: in-flight prefetched batches blur
                        # the edges, but over the measured steps this is
                        # the steady-state store traffic
                        out[f"gather_bytes_{mode}"] = \
                            int(tr.store.bytes_gathered - g0)
                        if tr.cache is not None:
                            out[f"cache_hit_rate_{mode}"] = \
                                tr.cache.hit_rate
                finally:
                    tr.close()
                if ref_losses is None:
                    ref_losses = losses
                elif max(abs(a - b)
                         for a, b in zip(ref_losses, losses)) != 0.0:
                    out["loss_match"] = False
        finally:
            if mode != "device":
                ds.features.close()     # mmap: unlink the tempfile
        if mode != "device":
            ss = out[f"host_stall_s_per_step_{mode}_sync"]
            sp = out[f"host_stall_s_per_step_{mode}_prefetch"]
            out[f"stall_reduction_{mode}"] = ss / max(sp, 1e-9)
            out[f"prefetch_reduces_stall_{mode}"] = bool(sp < ss)
    head = "mmap" if "mmap" in modes \
        else next((m for m in modes if m != "device"), None)
    if head is not None:
        out["headline_mode"] = head
        out["stall_reduction"] = out[f"stall_reduction_{head}"]
        out["prefetch_reduces_stall"] = out[f"prefetch_reduces_stall_{head}"]
        out["cache_hit_rate"] = out.get(f"cache_hit_rate_{head}", 0.0)
    return out


def run_feature_store_arm(n_cores: int = 4, *, smoke: bool = False,
                          spec: str = "ell+pipelined",
                          out_path: str = "BENCH_feature_store.json"
                          ) -> Dict:
    """Re-exec the feature-store measurement under a forced multi-device
    backend and write ``out_path`` (same child-process pattern as
    :func:`run_overlap_arm`: XLA_FLAGS must precede the jax import)."""
    kwargs: Dict = {"n_cores": n_cores, "spec": spec}
    if smoke:
        kwargs.update(scale=0.003, feat=32, hidden=32, batch=32, steps=6,
                      warmup=2, cache_capacity=64)
    child = (
        "import json, sys; sys.path.insert(0, '.');"
        "from benchmarks.epoch_time import measured_feature_store;"
        f"print(json.dumps(measured_feature_store(**{kwargs!r})))"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_cores} "
                        + env.get("XLA_FLAGS", "")).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", child], env=env, cwd=root,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"feature-store arm failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"## feature store ({n_cores} simulated cores, {spec}): "
          "device vs out-of-core, sync vs staged prefetch")
    print("mode,pipeline,host_stall_s_per_step,s_per_step")
    for mode in rec["modes"]:
        for pipe in ("sync", "prefetch"):
            print(f"{mode},{pipe},"
                  f"{rec[f'host_stall_s_per_step_{mode}_{pipe}']:.4f},"
                  f"{rec[f's_per_step_{mode}_{pipe}']:.4f}")
    for mode in rec["modes"]:
        if mode == "device":
            continue
        hr = rec.get(f"cache_hit_rate_{mode}")
        print(f"# {mode}: staged prefetch cuts host stall "
              f"{rec[f'stall_reduction_{mode}']:.1f}x (strictly less: "
              f"{rec[f'prefetch_reduces_stall_{mode}']})  gather "
              f"{rec[f'gather_bytes_{mode}'] / 1e6:.2f} MB"
              + ("" if hr is None else f"  cache hit-rate {hr:.2f}"))
    print(f"# loss bit-match across all modes: {rec['loss_match']}")
    print(f"# (wrote {out_path})")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--overlap", action="store_true",
                    help="measure the engine arms' step time vs the "
                         "coo+serial oracle")
    ap.add_argument("--input-pipeline", choices=["sync", "prefetch", "both"],
                    default=None,
                    help="measure the Trainer's per-step host-stall under "
                         "the given input pipeline(s); 'both' records the "
                         "sync-vs-prefetch overlap win")
    ap.add_argument("--spec", default="ell+pipelined",
                    help="engine spec for --input-pipeline")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (CI): implies a quick --overlap run")
    ap.add_argument("--cores", type=int, default=8,
                    help="simulated device count for the arm measurement")
    ap.add_argument("--arms", default=",".join(DEFAULT_ARMS),
                    help="comma-separated engine specs to measure against "
                         "the coo+serial oracle (replaces the old "
                         "--ell/--no-ell flag pair)")
    ap.add_argument("--feature-store", action="store_true",
                    help="measure feature residency (device vs host vs "
                         "mmap store) under sync vs staged-prefetch input "
                         "pipelines (writes BENCH_feature_store.json)")
    ap.add_argument("--topologies", action="store_true",
                    help="sweep every registered interconnect topology on "
                         "one bit-matching stream (exchange steps + bytes "
                         "+ measured speedups vs the allpairs reference; "
                         "writes BENCH_topology.json)")
    ap.add_argument("--auto", action="store_true",
                    help="autotune every spec, persist the winner to "
                         "BENCH_planner.json, and race Engine('auto') "
                         "against the best manual arm (writes "
                         "BENCH_auto.json)")
    ap.add_argument("--redundancy", action="store_true",
                    help="race the GraphACT-merged ELL engine "
                         "(merge=redundancy + mincom partitioning) against "
                         "the plain ELL arm on one bit-matching power-law "
                         "stream (writes BENCH_redundancy.json)")
    args = ap.parse_args()

    ran = False
    if args.overlap or args.smoke:
        arms = tuple(s for s in args.arms.split(",") if s)
        run_overlap_arm(args.cores, smoke=args.smoke, arms=arms)
        ran = True
    if args.topologies:
        run_topology_arm(min(args.cores, 4) if args.smoke else args.cores,
                         smoke=args.smoke, base_spec=args.spec)
        ran = True
    if args.auto:
        run_auto_arm(min(args.cores, 4) if args.smoke else args.cores,
                     smoke=args.smoke)
        ran = True
    if args.redundancy:
        run_redundancy_arm(min(args.cores, 4) if args.smoke else args.cores,
                           smoke=args.smoke)
        ran = True
    if args.feature_store:
        run_feature_store_arm(min(args.cores, 4) if args.smoke
                              else args.cores,
                              smoke=args.smoke, spec=args.spec)
        ran = True
    if args.input_pipeline is not None:
        modes = ("sync", "prefetch") if args.input_pipeline == "both" \
            else (args.input_pipeline,)
        run_input_pipeline_arm(args.cores, smoke=args.smoke,
                               spec=args.spec, modes=modes)
        ran = True
    if not ran:
        _table2_main()


def _table2_main() -> None:
    print("## analytic (paper scale, dataflow component of Table 2)")
    print("dataset,ops_naive_tab1,ops_naive_realistic,ops_ours,"
          "speedup_tab1,speedup_realistic")
    for r in analytic_epoch_ratio():
        print(f"{r['dataset']},{r['ops_naive']:.4g},"
              f"{r['ops_naive_realistic']:.4g},{r['ops_ours']:.4g},"
              f"{r['speedup_paper_literal']:.2f},{r['speedup']:.3f}")
    print("# paper Table 2 overall speedup vs HP-GNN: 1.03x-1.81x "
          "(dataflow + NoC components combined)")
    print("## measured (reduced scale, s/batch on CPU)")
    print("dataset,s_naive,s_ours,speedup")
    for name in ("flickr", "reddit"):
        m = measured_epoch(name)
        print(f"{name},{m['naive']:.4f},{m['ours']:.4f},{m['speedup']:.3f}")


if __name__ == "__main__":
    main()
