"""§Roofline table — read the dry-run records and emit the three-term
analysis per (arch × shape × mesh): seconds per term, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS usefulness ratio, and a one-line lever.

    PYTHONPATH=src python -m benchmarks.roofline [records.json] \
        [--arms block+pipelined,ell+pipelined]

``--arms`` names engine specs (validated against the registry — the old
``--overlap``/``--ell`` flag pair collapsed).  ``block+pipelined`` adds the
paper's Eq. 9 accounting: a serial schedule pays ``t_compute + t_memory +
t_collective`` while the double-buffered schedule pays ``max(t_collective,
t_compute + t_memory)`` — the table then shows the per-cell bound on what
the pipelined aggregation arm can win.  ``ell+pipelined`` stacks the
pre-reduced ELL bound on top (the scatter's read-modify-write HBM traffic
eliminated — see :func:`ell_rows` for the assumption).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

DEFAULT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "dryrun_records.json")

LEVERS = {
    "compute": "raise arithmetic intensity: fuse epilogues, bf16 logits, "
               "larger per-device batch",
    "memory": "cut HBM traffic: fuse softmax/CE, bf16 intermediates, "
              "remat policy tuning, flash-block sizing",
    "collective": "cut wire bytes: bf16 collectives, 2D all-reduce, "
                  "pre-reduction before exchange (paper's Block-Message "
                  "merge), overlap with compute",
}


def load(path: str = DEFAULT) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def table(records: List[Dict], mesh: str = "16x16") -> List[Dict]:
    rows = []
    for r in records:
        if r.get("skipped") or r.get("mesh") != mesh:
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_compute_ms": t["t_compute"] * 1e3,
            "t_memory_ms": t["t_memory"] * 1e3,
            "t_collective_ms": t["t_collective"] * 1e3,
            "dominant": t["dominant"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_frac": t["t_compute"] / max(
                t["t_compute"], t["t_memory"], t["t_collective"]),
        })
    return rows


def overlap_rows(rows: List[Dict]) -> List[Dict]:
    """Eq. 9 accounting per cell: serial = sum of terms, overlapped =
    max(wire, MAC+HBM) — the bound on the double-buffered schedule's win."""
    out = []
    for r in rows:
        serial = (r["t_compute_ms"] + r["t_memory_ms"]
                  + r["t_collective_ms"])
        local = r["t_compute_ms"] + r["t_memory_ms"]
        overlapped = max(r["t_collective_ms"], local)
        out.append({**r, "t_serial_ms": serial,
                    "t_overlap_ms": overlapped,
                    "overlap_gain": serial / max(overlapped, 1e-12)})
    return out


def ell_rows(orows: List[Dict], scatter_frac: float = 0.3) -> List[Dict]:
    """Pre-reduced ELL bound on top of the Eq. 9 overlap bound.

    The ELL engine replaces the aggregation's segment-sum scatter with a
    gather + degree-axis reduction: the scatter's read-modify-write HBM
    traffic (it touches every accumulator row twice) disappears.
    ``scatter_frac`` is the assumed share of the memory term that is
    scatter RMW traffic; eliminating the read half of it scales the memory
    term by ``(1 - scatter_frac/2)``.  This is an ANALYTIC bound arm — the
    measured counterpart is ``epoch_time --overlap``'s ELL arm.
    """
    out = []
    for r in orows:
        mem_ell = r["t_memory_ms"] * (1 - scatter_frac / 2)
        t_ell = max(r["t_collective_ms"], r["t_compute_ms"] + mem_ell)
        out.append({**r, "t_ell_ms": t_ell,
                    "ell_gain": r["t_serial_ms"] / max(t_ell, 1e-12)})
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("records", nargs="?", default=DEFAULT)
    ap.add_argument("--arms", default="",
                    help="comma-separated engine specs whose analytic "
                         "bounds to stack: block+pipelined (Eq. 9 overlap "
                         "bound), ell+pipelined (scatter-free bound on "
                         "top); replaces the old --overlap/--ell flags")
    ap.add_argument("--scatter-frac", type=float, default=0.3,
                    help="assumed scatter-RMW share of the memory term "
                         "the ELL engine eliminates")
    args = ap.parse_args()
    arms = tuple(s.strip() for s in args.arms.split(",") if s.strip())
    if arms:
        # import only when specs were named: the bare table print stays a
        # stdlib-only script with no jax/repro dependency
        from repro.engine import EngineConfig
        arms = tuple(EngineConfig.from_spec(s).spec for s in arms)
    want_overlap = "block+pipelined" in arms
    want_ell = "ell+pipelined" in arms
    records = load(args.records)
    for mesh in ("16x16", "2x16x16"):
        rows = table(records, mesh)
        if not rows:
            continue
        print(f"## mesh {mesh}")
        print("arch,shape,t_compute_ms,t_memory_ms,t_collective_ms,"
              "dominant,useful_flops_ratio,roofline_frac")
        for r in sorted(rows, key=lambda r: r["roofline_frac"]):
            print(f"{r['arch']},{r['shape']},{r['t_compute_ms']:.2f},"
                  f"{r['t_memory_ms']:.2f},{r['t_collective_ms']:.2f},"
                  f"{r['dominant']},{r['useful_ratio']:.3f},"
                  f"{r['roofline_frac']:.3f}")
        doms = {}
        for r in rows:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"# dominant-term census: {doms}")
        for k, v in LEVERS.items():
            if doms.get(k):
                print(f"# {k}-bound lever: {v}")
        if want_overlap or want_ell:
            print(f"## mesh {mesh} — Eq. 9 overlap bound "
                  "(serial=sum, overlapped=max(wire, MAC+HBM))")
            print("arch,shape,t_serial_ms,t_overlap_ms,overlap_gain")
            orows = overlap_rows(rows)
            for r in sorted(orows, key=lambda r: -r["overlap_gain"]):
                print(f"{r['arch']},{r['shape']},{r['t_serial_ms']:.2f},"
                      f"{r['t_overlap_ms']:.2f},{r['overlap_gain']:.3f}")
            best = max(orows, key=lambda r: r["overlap_gain"])
            print(f"# best overlap win: {best['arch']}×{best['shape']} "
                  f"{best['overlap_gain']:.2f}x — the block+pipelined arm "
                  "(epoch_time --overlap) realizes this bound")
        if want_ell:
            print(f"## mesh {mesh} — pre-reduced ELL bound "
                  f"(scatter RMW share {args.scatter_frac:.0%} of HBM term "
                  "eliminated)")
            print("arch,shape,t_overlap_ms,t_ell_ms,ell_gain")
            erows = ell_rows(orows, args.scatter_frac)
            for r in sorted(erows, key=lambda r: -r["ell_gain"]):
                print(f"{r['arch']},{r['shape']},{r['t_overlap_ms']:.2f},"
                      f"{r['t_ell_ms']:.2f},{r['ell_gain']:.3f}")
            best = max(erows, key=lambda r: r["ell_gain"])
            print(f"# best ELL win: {best['arch']}×{best['shape']} "
                  f"{best['ell_gain']:.2f}x — the ell+pipelined arm "
                  "(epoch_time --overlap) measures this")


if __name__ == "__main__":
    main()
