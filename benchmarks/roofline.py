"""§Roofline table — read the dry-run records and emit the three-term
analysis per (arch × shape × mesh): seconds per term, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS usefulness ratio, and a one-line lever.

    PYTHONPATH=src python -m benchmarks.roofline [records.json]
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

DEFAULT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "dryrun_records.json")

LEVERS = {
    "compute": "raise arithmetic intensity: fuse epilogues, bf16 logits, "
               "larger per-device batch",
    "memory": "cut HBM traffic: fuse softmax/CE, bf16 intermediates, "
              "remat policy tuning, flash-block sizing",
    "collective": "cut wire bytes: bf16 collectives, 2D all-reduce, "
                  "pre-reduction before exchange (paper's Block-Message "
                  "merge), overlap with compute",
}


def load(path: str = DEFAULT) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def table(records: List[Dict], mesh: str = "16x16") -> List[Dict]:
    rows = []
    for r in records:
        if r.get("skipped") or r.get("mesh") != mesh:
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_compute_ms": t["t_compute"] * 1e3,
            "t_memory_ms": t["t_memory"] * 1e3,
            "t_collective_ms": t["t_collective"] * 1e3,
            "dominant": t["dominant"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_frac": t["t_compute"] / max(
                t["t_compute"], t["t_memory"], t["t_collective"]),
        })
    return rows


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else DEFAULT
    records = load(path)
    for mesh in ("16x16", "2x16x16"):
        rows = table(records, mesh)
        if not rows:
            continue
        print(f"## mesh {mesh}")
        print("arch,shape,t_compute_ms,t_memory_ms,t_collective_ms,"
              "dominant,useful_flops_ratio,roofline_frac")
        for r in sorted(rows, key=lambda r: r["roofline_frac"]):
            print(f"{r['arch']},{r['shape']},{r['t_compute_ms']:.2f},"
                  f"{r['t_memory_ms']:.2f},{r['t_collective_ms']:.2f},"
                  f"{r['dominant']},{r['useful_ratio']:.3f},"
                  f"{r['roofline_frac']:.3f}")
        doms = {}
        for r in rows:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"# dominant-term census: {doms}")
        for k, v in LEVERS.items():
            if doms.get(k):
                print(f"# {k}-bound lever: {v}")


if __name__ == "__main__":
    main()
