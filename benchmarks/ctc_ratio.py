"""Fig. 10/11 reproduction — compute:communication ratio per core and
multi-core utilization under power-law degree skew.

Core model (paper §5.3):
  * nodes map to cores by GLOBAL id range (the Fig. 7 address decode:
    high bits = core id), so hub-heavy regions of a power-law graph load
    their owner cores harder — the source of Fig. 11(b)'s utilization gap;
  * t_comb+agg per core = (feature rows × d × h + incident edges × h) MACs
    at 256 MACs/cycle (the paper's PE array);
  * t_message per core = received message-LINES / 4 input links, where one
    256-f32 feature = 16 × 64 B lines, messages = post-compression Block
    Messages (Alg. 1 latency adds the routed-cycle term);
  * Eq. 9:  t_core = max(t_message, t_comb + t_agg);
    Eq. 10: t_layer = max over cores; utilization = mean(t_core) / max.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.blockmsg import wave_statistics
from repro.core.routing import route_messages
from repro.graph import NeighborSampler, make_dataset

MACS_PER_CYCLE = 256          # paper's PE array
LINE_BYTES = 64
N_CORES = 16
N_LINKS = 4                   # 4-D hypercube: one input line per dimension


def core_times(name: str, *, scale: float = 0.02, batch: int = 1024,
               hidden: int = 256, seed: int = 0) -> Dict:
    ds = make_dataset(name, scale=scale)        # true per-dataset feat_dim
    d_in = ds.stats.feat_dim
    sampler = NeighborSampler(ds.graph, fanouts=(10, 25), pad_multiple=16,
                              seed=seed)
    rng = np.random.default_rng(seed)
    seeds = rng.permutation(ds.graph.n_nodes)[:batch]
    mb = sampler.sample(seeds, rng=np.random.default_rng(seed))
    A = mb.layers[-1]                       # input layer (the heavy hop)
    n = ds.graph.n_nodes

    # global-id core mapping (Fig. 7 address decode on the FULL graph)
    frontier_core = (mb.input_nodes.astype(np.int64) * N_CORES) // n
    dst_nodes = mb.input_nodes[:A.n_dst] if A.n_dst <= len(mb.input_nodes) \
        else np.pad(mb.input_nodes, (0, A.n_dst - len(mb.input_nodes)))
    dst_core = (dst_nodes.astype(np.int64) * N_CORES) // n

    rows = np.asarray(A.rows)
    cols = np.asarray(A.cols)
    vals = np.asarray(A.vals)
    live = vals != 0
    r_core = dst_core[np.minimum(rows[live], len(dst_core) - 1)]
    c_core = frontier_core[np.minimum(cols[live], len(frontier_core) - 1)]

    # compute per core: combination of owned frontier rows (d_in × hidden
    # GEMM — the paper's input layer) + aggregation MACs over incident edges
    rows_per_core = np.bincount(frontier_core, minlength=N_CORES)
    comb = rows_per_core * d_in * hidden / MACS_PER_CYCLE
    agg = np.bincount(r_core, minlength=N_CORES,
                      weights=np.ones(live.sum())) * hidden / MACS_PER_CYCLE

    # messages: per (dst_core, src_core, dst_row) after local pre-reduction
    key = (r_core.astype(np.int64) * N_CORES + c_core) * (2 ** 20) \
        + rows[live].astype(np.int64)
    uniq_msgs, msg_key_inv = np.unique(key, return_inverse=True)
    msg_dst = (uniq_msgs // (2 ** 20)) // N_CORES
    lines_per_msg = d_in * 4 // LINE_BYTES      # messages carry d_in features
    incoming = np.bincount(msg_dst.astype(np.int64), minlength=N_CORES)
    # subtract local (same-core) messages — they never touch the network
    same = (r_core == c_core)
    local_key = key[same]
    local_msgs = np.bincount(
        ((np.unique(local_key) // (2 ** 20)) // N_CORES).astype(np.int64),
        minlength=N_CORES)
    net_msgs = np.maximum(incoming - local_msgs, 0)
    t_msg = net_msgs * lines_per_msg / N_LINKS
    # routed-latency term from one representative Algorithm-1 wave
    src, dst = np.arange(16), np.roll(np.arange(16), 5)
    lat = route_messages(np.tile(src, 4), np.tile(dst, 4), seed=seed).cycles

    t_core = np.maximum(t_msg + lat, comb + agg)          # Eq. 9
    util = float(t_core.mean() / t_core.max())            # Eq. 10
    return {
        "dataset": name,
        "ctc_ratio": float((comb + agg).mean() / max(t_msg.mean(), 1.0)),
        "utilization": util,
        "core_skew": float(t_core.max() / np.median(t_core)),
        "compression": float(live.sum() / max(len(uniq_msgs), 1)),
    }


def main() -> None:
    print("dataset,ctc_ratio,utilization,core_skew,msg_compression")
    rows = [core_times(n) for n in ("flickr", "reddit", "yelp",
                                    "amazonproducts")]
    for r in rows:
        print(f"{r['dataset']},{r['ctc_ratio']:.3f},{r['utilization']:.3f},"
              f"{r['core_skew']:.3f},{r['compression']:.2f}")
    by = {r["dataset"]: r for r in rows}
    print(f"# paper Fig. 10: per-core compute:comm ≈ 1:1 "
          f"(ours: {np.mean([r['ctc_ratio'] for r in rows]):.2f}); "
          f"Fig. 11(b): skewed graphs lose multi-core utilization "
          f"(yelp={by['yelp']['utilization']:.3f} "
          f"amazon={by['amazonproducts']['utilization']:.3f} vs "
          f"reddit={by['reddit']['utilization']:.3f})")


if __name__ == "__main__":
    main()
