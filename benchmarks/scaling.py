"""Scaling analysis — does the design hold at 1000+ nodes?

Analytic per-device wire bytes vs core count P for the schedules this
framework ships, at the paper's batch geometry (frontier 11264 → batch 1024
rows, d = 256) and for the LM gradient sync (1.24 B-param model):

  * hypercube aggregation (pre-reduced):  n_dst·(1−1/P)·d·4
  * UMA all-gather baseline:              n_src·(1−1/P)·d·4
  * f32 ring grad all-reduce:             2·(1−1/P)·params·4
  * int8 EF-compressed all-reduce:        ≈ 2·(1−1/P)·params·1

Both aggregation schedules asymptote (per-device bytes are flat in P), so
scale-out is latency- not bandwidth-limited — the log₂P round count is what
grows, which the dry-run's 512-way mesh exercises.  Gradient sync is flat
per device too; compression buys a constant 4×.
"""
from __future__ import annotations

from typing import Dict, List

from repro.distributed.aggregate import schedule_bytes

PARAMS = 1.24e9          # llama3.2-1b
D = 256
N_DST, N_SRC = 1024, 11264


def rows() -> List[Dict]:
    out = []
    for p in (4, 16, 64, 256, 1024, 4096):
        sb = schedule_bytes(N_DST * (p // 4 if p >= 4 else 1),
                            N_SRC * (p // 4 if p >= 4 else 1), D, p)
        # weak scaling: batch grows with P, per-device work constant
        grad = 2 * (1 - 1 / p) * PARAMS * 4
        out.append({
            "P": p,
            "rounds": p.bit_length() - 1,
            "hyper_MB_per_dev": sb["hypercube_bytes_per_device"] / p / 1e6,
            "uma_MB_per_dev": sb["uma_bytes_per_device"] / p / 1e6,
            "grad_f32_MB": grad / 1e6,
            "grad_int8_MB": grad / 4 / 1e6,
        })
    return out


def main() -> None:
    print("P,hypercube_rounds,hyper_MB/dev,uma_MB/dev,"
          "grad_f32_MB/dev,grad_int8_MB/dev")
    for r in rows():
        print(f"{r['P']},{r['rounds']},{r['hyper_MB_per_dev']:.2f},"
              f"{r['uma_MB_per_dev']:.2f},{r['grad_f32_MB']:.0f},"
              f"{r['grad_int8_MB']:.0f}")
    print("# weak scaling: per-device aggregation bytes flat in P — "
          "scale-out costs log2(P) rounds of latency, not bandwidth; "
          "EF-int8 compression is a flat 4x on the gradient sync")


if __name__ == "__main__":
    main()
