"""Serving benchmark — latency SLOs for the online GCN inference service.

    PYTHONPATH=src python -m benchmarks.serving [--smoke]

Measures the :mod:`repro.serving` subsystem end to end on a trained
checkpoint (a short multi-device Trainer run — serving loads what a real
deployment would):

* **bit-match probe** — a mixed stream of queries and edge/feature updates
  where every incremental query must bit-match a cold full recompute;
* **coalesce burst** — concurrent duplicate-heavy submissions through the
  queue, measuring requests-per-computed-row;
* **paired open-loop arms** — the SAME Poisson/zipf trace replayed against
  the incremental engine (historical-embedding cache on) and the cold
  engine (cache bypassed, every query a full L-hop recompute), reporting
  p50/p99 latency and throughput-at-SLO for each.

Writes ``BENCH_serving.json``; ``run.py --smoke`` gates ``bit_match``,
``coalesce_factor > 1`` and ``incremental_vs_cold_throughput > 1`` — the
incremental path has to actually WIN under the SLO, not just match logits.

Methodology note: the two open-loop arms replay one identical trace
back-to-back in one process, so host load is common-mode for the
throughput RATIO (the gated metric); the absolute p50/p99 milliseconds are
load-sensitive and tracked warn-only in ``compare.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from typing import Dict


def measured_serving(*, n_cores: int = 4, scale: float = 0.004,
                     feat: int = 32, hidden: int = 32, batch: int = 32,
                     train_steps: int = 20, train_spec: str = "ell+pipelined",
                     spec: str = "coo+serial", rate: float = 150.0,
                     duration: float = 2.0, slo_ms: float = 50.0,
                     max_batch: int = 8, max_wait_ms: float = 2.0,
                     cache_capacity: int = 4096, update_rounds: int = 10,
                     burst: int = 64, burst_pool: int = 12,
                     seed: int = 0) -> Dict:
    """Train → checkpoint → serve; returns the serving record.

    Needs ``n_cores`` devices for the training leg
    (:func:`run_serving_arm` re-execs under forced ``XLA_FLAGS``)."""
    import numpy as np

    from repro.launch.serve import mixed_stream_bit_match
    from repro.launch.trainer import Trainer
    from repro.serving import (InferenceEngine, InferenceService,
                               poisson_trace)

    with tempfile.TemporaryDirectory(prefix="repro_bench_serve_") as ckpt:
        trainer = Trainer(train_spec, "flickr", n_cores=n_cores,
                          scale=scale, feat_dim=feat, hidden=hidden,
                          batch_size=batch,
                          pad_multiple=max(64, n_cores),
                          ckpt_dir=ckpt, log_every=0, seed=seed)
        trainer.train_steps(train_steps)
        trainer.save(sync=True)
        dataset = trainer.dataset
        trainer.close()

        def fresh_engine() -> InferenceEngine:
            return InferenceEngine(spec, dataset.graph, dataset.features,
                                   ckpt_dir=ckpt,
                                   cache_capacity=cache_capacity,
                                   max_batch=max_batch)

        rec: Dict = {"n_cores": n_cores, "spec": None,
                     "train_spec": train_spec, "train_steps": train_steps,
                     "scale": scale, "feat": feat, "hidden": hidden,
                     "rate": rate, "duration": duration, "slo_ms": slo_ms,
                     "max_batch": max_batch, "max_wait_ms": max_wait_ms,
                     "cache_capacity": cache_capacity, "seed": seed}

        # -- bit-match probe: mixed queries + graph/feature updates ----------
        probe = fresh_engine()
        rec["spec"] = probe.spec
        rec["bit_match"] = mixed_stream_bit_match(probe, update_rounds,
                                                  seed)
        rec["probe_cache"] = probe.cache.stats()

        # -- coalesce burst: concurrent duplicate-heavy submissions ----------
        eng = fresh_engine()
        eng.query([0], use_cache=False)   # warm compile off the clock
        eng.query([0])
        svc = InferenceService(eng, max_batch=max_batch,
                               max_wait=max_wait_ms * 1e-3)
        rng = np.random.default_rng(seed)
        pool = rng.integers(0, eng.graph.n_nodes, burst_pool)
        for node in rng.choice(pool, burst):
            svc.submit(int(node), now=0.0)
        svc.drain(now=0.0)
        rec["coalesce_factor"] = svc.queue.coalesce_factor
        rec["burst"] = svc.queue.stats()

        # -- paired open-loop arms: cold first, then incremental -------------
        trace = poisson_trace(rate, duration, eng.graph.n_nodes, seed=seed)
        rec["offered"] = len(trace)
        slo = slo_ms * 1e-3
        cold_eng = fresh_engine()
        # rehearsal pass: replay the identical trace once per arm OFF the
        # record, so every jit shape bucket the trace will hit is compiled
        # before anything is measured — compile is deployment warmup, not
        # serving latency (one uncompiled bucket mid-replay is a ~400ms
        # p99 outlier).  The measured arms then run back-to-back so host
        # load stays common-mode for the gated throughput ratio.
        InferenceService(cold_eng, max_batch=max_batch,
                         max_wait=max_wait_ms * 1e-3,
                         use_cache=False).replay(trace, slo=slo)
        InferenceService(eng, max_batch=max_batch,
                         max_wait=max_wait_ms * 1e-3).replay(trace, slo=slo)
        cold = InferenceService(cold_eng, max_batch=max_batch,
                                max_wait=max_wait_ms * 1e-3,
                                use_cache=False).replay(trace, slo=slo)
        inc_svc = InferenceService(eng, max_batch=max_batch,
                                   max_wait=max_wait_ms * 1e-3)
        inc = inc_svc.replay(trace, slo=slo)
        for k in ("completed", "p50_ms", "p99_ms", "mean_ms", "within_slo",
                  "throughput_at_slo", "wall_s"):
            rec[k] = inc[k]
            rec[f"cold_{k}"] = cold[k]
        # keyed separately: rec["coalesce_factor"] is the BURST's number
        # (the gated one — concurrent duplicate demand); the open-loop
        # replay at these rates is mostly singleton batches
        rec["replay_coalesce_factor"] = inc["coalesce_factor"]
        rec["cold_replay_coalesce_factor"] = cold["coalesce_factor"]
        rec["incremental_vs_cold_throughput"] = (
            inc["throughput_at_slo"] / max(cold["throughput_at_slo"],
                                           1e-9))
        rec["cache_hit_rate"] = eng.cache.hit_rate
        rec["cache"] = eng.cache.stats()
        rec["engine"] = {k: v for k, v in eng.stats().items()
                         if isinstance(v, (int, float, str, bool))}
    return rec


def run_serving_arm(n_cores: int = 4, *, smoke: bool = False,
                    out_path: str = "BENCH_serving.json") -> Dict:
    """Re-exec :func:`measured_serving` under a forced multi-device
    backend and write ``out_path`` (same child-process pattern as the
    other arms: XLA_FLAGS must precede the jax import)."""
    kwargs: Dict = {"n_cores": n_cores}
    if smoke:
        # rate/SLO sized to stress the arms apart on a CI host: the cold
        # full-recompute path sits near its single-worker capacity at this
        # rate, so its queueing delay blows through the SLO while the
        # incremental path (smaller per-batch todo sets) stays inside it
        kwargs.update(scale=0.003, feat=32, hidden=32, batch=32,
                      train_steps=10, rate=240.0, duration=1.5,
                      slo_ms=25.0, update_rounds=8, burst_pool=8)
    child = (
        "import json, sys; sys.path.insert(0, '.');"
        "from benchmarks.serving import measured_serving;"
        f"print(json.dumps(measured_serving(**{kwargs!r})))"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_cores} "
                        + env.get("XLA_FLAGS", "")).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", child], env=env, cwd=root,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"serving arm failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"## serving ({n_cores} simulated cores, {rec['spec']}, "
          f"trained {rec['train_steps']} steps on {rec['train_spec']})")
    print("arm,completed,p50_ms,p99_ms,throughput_at_slo")
    print(f"incremental,{rec['completed']},{rec['p50_ms']:.2f},"
          f"{rec['p99_ms']:.2f},{rec['throughput_at_slo']:.1f}")
    print(f"cold,{rec['cold_completed']},{rec['cold_p50_ms']:.2f},"
          f"{rec['cold_p99_ms']:.2f},{rec['cold_throughput_at_slo']:.1f}")
    print(f"# bit_match (mixed update/query stream): {rec['bit_match']}")
    print(f"# coalesce_factor (burst): {rec['coalesce_factor']:.2f}x  "
          f"embedding-cache hit-rate: {rec['cache_hit_rate']:.2f}")
    print(f"# incremental vs cold throughput@SLO({rec['slo_ms']:.0f}ms): "
          f"{rec['incremental_vs_cold_throughput']:.2f}x")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-cores", type=int, default=4)
    args = ap.parse_args()
    run_serving_arm(args.n_cores, smoke=args.smoke)


if __name__ == "__main__":
    main()
