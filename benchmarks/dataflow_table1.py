"""Table 1 reproduction — time/storage complexity of CoAg / AgCo / Ours-*,
analytically on the paper's batch shapes AND measured on compiled steps.

Analytic side: the estimator's cost model evaluated at the paper's setup
(batch 1024, fanouts (25, 10), hidden 256) for each dataset — reproduces
Eqs. 5-8's positive gaps.

Measured side: residual bytes (what forward must keep for backward) and the
count of large transposes in the compiled HLO, ours vs naive — the two
contracts the redesign claims.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baseline import gcn_layer_baseline, residual_bytes_naive
from repro.core.estimator import (LayerShape, storage_naive, storage_ours,
                                  time_naive, time_ours)
from repro.core.gcn import gcn_layer, residual_bytes
from repro.graph.coo import from_edges
from repro.graph.datasets import DATASET_STATS

BATCH, FANOUTS, HIDDEN = 1024, (10, 25), 256


def paper_layer_shapes(name: str) -> List[LayerShape]:
    st = DATASET_STATS[name]
    avg_deg = st.n_edges * 2 / st.n_nodes
    n1 = BATCH * (min(FANOUTS[0], avg_deg) + 1)          # hop-1 nodes
    n2 = n1 * (min(FANOUTS[1], avg_deg) + 1)             # hop-2 frontier
    e1 = BATCH * (FANOUTS[0] + 1)
    e2 = n1 * (FANOUTS[1] + 1)
    return [
        LayerShape(b=BATCH, n=BATCH, nbar=int(n1), d=HIDDEN,
                   h=st.n_classes, e=int(e1), c=st.n_classes),
        LayerShape(b=BATCH, n=int(n1), nbar=int(n2), d=st.feat_dim,
                   h=HIDDEN, e=int(e2), c=st.n_classes),
    ]


def analytic_rows() -> List[Dict]:
    rows = []
    for name in DATASET_STATS:
        for s in paper_layer_shapes(name)[1:]:           # input layer
            for order in ("coag", "agco"):
                rows.append({
                    "dataset": name, "order": order,
                    "tc_naive": time_naive(s, order),
                    "tc_ours": time_ours(s, order),
                    "tc_gap": time_naive(s, order) - time_ours(s, order),
                    "sc_naive": storage_naive(s, order),
                    "sc_ours": storage_ours(s, order),
                    "sc_gap": storage_naive(s, order) - storage_ours(s, order),
                })
    return rows


def measured_contracts(rng_seed: int = 0) -> Dict:
    rng = np.random.default_rng(rng_seed)
    n_dst, n_src, d, h, e = 256, 1024, 128, 64, 4096
    A = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                   rng.standard_normal(e).astype(np.float32), n_dst, n_src)
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, h)), jnp.float32)

    def count_big_transposes(fn):
        import re
        txt = jax.jit(fn).lower(x, w).compile().as_text()
        op = re.compile(r"f32\[(\d+),(\d+)\]\{[^}]*\}\s+transpose\(")
        n = 0
        for line in txt.splitlines():
            m = op.search(line)
            if m and int(m.group(1)) * int(m.group(2)) >= n_dst * d:
                n += 1
        return n

    def g_ours(x, w):
        return jax.grad(lambda x, w: jnp.sum(gcn_layer(A, x, w) ** 2),
                        argnums=(0, 1))(x, w)

    def g_naive(x, w):
        return jax.grad(
            lambda x, w: jnp.sum(gcn_layer_baseline(A, x, w) ** 2),
            argnums=(0, 1))(x, w)

    # wall-time of the jitted train-layer grad (CPU, order-of-magnitude)
    def timed(fn):
        j = jax.jit(fn)
        j(x, w)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            j(x, w)[0].block_until_ready()
        return (time.perf_counter() - t0) / 20 * 1e6

    return {
        "transposes_ours": count_big_transposes(g_ours),
        "transposes_naive": count_big_transposes(g_naive),
        "residual_bytes_ours": residual_bytes("coag", n_dst, n_src, d, h),
        "residual_bytes_naive": residual_bytes_naive("coag", n_dst, n_src,
                                                     d, h, e),
        "us_ours": timed(g_ours),
        "us_naive": timed(g_naive),
    }


def main() -> None:
    print("dataset,order,tc_naive,tc_ours,tc_gap,sc_naive,sc_ours,sc_gap")
    for r in analytic_rows():
        print(f"{r['dataset']},{r['order']},{r['tc_naive']:.3g},"
              f"{r['tc_ours']:.3g},{r['tc_gap']:.3g},{r['sc_naive']:.3g},"
              f"{r['sc_ours']:.3g},{r['sc_gap']:.3g}")
        assert r["tc_gap"] > 0 and r["sc_gap"] > 0   # Eqs. 5-8
    m = measured_contracts()
    print(f"# measured: big-transposes ours={m['transposes_ours']} "
          f"naive={m['transposes_naive']}; residual bytes "
          f"ours={m['residual_bytes_ours']} naive={m['residual_bytes_naive']} "
          f"({m['residual_bytes_naive']/m['residual_bytes_ours']:.2f}x); "
          f"grad step ours={m['us_ours']:.0f}us naive={m['us_naive']:.0f}us")


if __name__ == "__main__":
    main()
