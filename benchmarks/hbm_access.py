"""Fig. 1 analogue — access locality vs bandwidth, on this machine + in HLO.

The paper's Fig. 1 shows HBM pseudo-channel bandwidth collapsing when
multiple non-local AXI masters hit one channel (−13.7% … −35.1%).  A TPU has
no shared pseudo-channels, so the transferable claim becomes: *random
fine-grained gathers waste memory bandwidth vs sequential block reads, and
moving aggregation traffic onto the interconnect with pre-reduction beats
raw remote reads.*  Two measurements:

  1. gather bandwidth vs "burst length" (contiguous block size) on this
     host — the memory-system shape of Fig. 1(a);
  2. wire bytes of the NUMA/hypercube schedule vs the UMA all-gather
     baseline from the compiled HLO, per dataset density (Fig. 1(b-d)'s
     contention, reborn as collective bytes).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.aggregate import schedule_bytes


def gather_bandwidth(total_mb: int = 64, d: int = 256) -> List[Dict]:
    """Read `total_mb` MB via gathers of varying contiguous block length."""
    n_rows = total_mb * 1024 * 1024 // (4 * d)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (n_rows, d)), jnp.float32)
    rows = []
    rng = np.random.default_rng(1)
    for burst in (1, 4, 16, 64, 256):
        n_blocks = n_rows // burst
        starts = rng.integers(0, n_blocks, n_blocks).astype(np.int32) * burst
        idx = (starts[:, None] + np.arange(burst)[None, :]).reshape(-1)
        idx_j = jnp.asarray(idx)

        @jax.jit
        def read(x, idx_j):
            return x[idx_j].sum(0)

        read(x, idx_j).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            read(x, idx_j).block_until_ready()
        dt = (time.perf_counter() - t0) / 5
        rows.append({"burst_rows": burst,
                     "GBps": total_mb / 1024 / dt})
    seq = rows[-1]["GBps"]
    for r in rows:
        r["frac_of_seq"] = r["GBps"] / seq
    return rows


def numa_vs_uma_bytes() -> List[Dict]:
    """Analytic wire bytes per device for the two schedules, across the
    sampled-batch shapes of the paper's four datasets (d = hidden 256)."""
    out = []
    for name, (n_dst, n_src) in {
            "flickr": (1024, 11264), "reddit": (1024, 11264),
            "yelp": (1024, 11264), "amazonproducts": (1024, 11264)}.items():
        sb = schedule_bytes(n_dst, n_src, d=256, n_cores=16)
        out.append({"dataset": name, **sb})
    return out


def main() -> None:
    print("## gather bandwidth vs burst length (Fig. 1(a) analogue)")
    print("burst_rows,GBps,frac_of_sequential")
    for r in gather_bandwidth():
        print(f"{r['burst_rows']},{r['GBps']:.2f},{r['frac_of_seq']:.3f}")
    print("## NUMA hypercube vs UMA all-gather wire bytes (Fig. 1(b-d))")
    print("dataset,hypercube_B,uma_B,ratio")
    for r in numa_vs_uma_bytes():
        print(f"{r['dataset']},{r['hypercube_bytes_per_device']},"
              f"{r['uma_bytes_per_device']},{r['ratio']:.2f}")


if __name__ == "__main__":
    main()
